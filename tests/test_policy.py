"""Policy registry namespace: the MLP default's back-compat, the logits
spec dispatch (string vs callable), and the transformer policy riding the
flat θ stack through DecByzPG (tentpole (c) of the sharded-aggregation
PR)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
from repro.rl.envs import make_env
from repro.rl.policy import (Policy, mlp_logits, policy_logits,
                             policy_unraveler, resolve_policy)

ENV = make_env("cartpole", horizon=10)
KEY = jax.random.PRNGKey(0)

TINY_TF = ("transformer(arch='qwen2.5-3b', d_model=32, n_layers=1, "
           "n_heads=2, d_ff=64)")


def test_policy_logits_string_is_mlp():
    from repro.rl.policy import init_mlp, mlp_sizes
    params = init_mlp(KEY, mlp_sizes(ENV, (8,)))
    obs = jax.random.normal(KEY, (5, ENV.obs_dim))
    np.testing.assert_array_equal(policy_logits(params, obs, "relu"),
                                  mlp_logits(params, obs, "relu"))


def test_policy_logits_callable_dispatch():
    fn = lambda params, obs: params["w"] * obs.sum(-1, keepdims=True)
    got = policy_logits({"w": jnp.float32(2.0)},
                        jnp.ones((3, 4)), fn)
    np.testing.assert_array_equal(got, 8.0 * jnp.ones((3, 1)))


def test_mlp_policy_matches_legacy_fields():
    """resolve_policy('mlp') reproduces the historical init_mlp/activation
    wiring from the config's hidden/activation fields; explicit spec
    kwargs win."""
    from repro.rl.policy import mlp_unraveler
    cfg = DecByzPGConfig(hidden=(8, 8), activation="tanh")
    pol = resolve_policy(cfg, ENV)
    assert pol.logits == "tanh"
    _, d = policy_unraveler(pol)
    assert d == mlp_unraveler(ENV, (8, 8))[1]
    cfg2 = DecByzPGConfig(policy="mlp(hidden=(4,), activation='relu')",
                          hidden=(8, 8), activation="tanh")
    pol2 = resolve_policy(cfg2, ENV)
    assert pol2.logits == "relu"
    assert policy_unraveler(pol2)[1] == mlp_unraveler(ENV, (4,))[1]


def test_default_policy_field_preserves_decbyzpg_trace():
    """Adding the policy field must not change the default path: an
    explicit policy='mlp' is the same static config as the default, and
    the run reuses the same compiled loop."""
    kw = dict(K=3, n_byz=1, attack="sign_flip", aggregator="rfa",
              agreement="gda", kappa=1, N=4, B=2, hidden=(8,))
    out1 = run_decbyzpg(ENV, DecByzPGConfig(**kw), 3)
    n = engine.compile_count()
    out2 = run_decbyzpg(ENV, DecByzPGConfig(policy="mlp", **kw), 3)
    assert engine.compile_count() == n
    np.testing.assert_array_equal(out1["returns"], out2["returns"])


def test_transformer_policy_logits_shapes():
    pol = resolve_policy(DecByzPGConfig(policy=TINY_TF), ENV)
    params = pol.init(KEY)
    assert "frontend_proj" in params
    obs1 = jax.random.normal(KEY, (ENV.obs_dim,))
    obsB = jnp.stack([obs1] * 3)
    l1 = policy_logits(params, obs1, pol.logits)
    lB = policy_logits(params, obsB, pol.logits)
    assert l1.shape == (ENV.n_actions,)
    assert lB.shape == (3, ENV.n_actions)
    np.testing.assert_allclose(lB[0], l1, atol=1e-6)
    assert np.all(np.isfinite(np.asarray(lB)))


def test_transformer_policy_rejects_small_model():
    with pytest.raises(ValueError, match="d_model"):
        resolve_policy(DecByzPGConfig(
            policy="transformer(arch='qwen2.5-3b', d_model=2, n_heads=2)"),
            ENV)


@pytest.mark.slow
def test_decbyzpg_transformer_end_to_end():
    """DecByzPG trains a transformer policy through the full fused scan:
    robust aggregation + agreement over the flat transformer stack, cache
    hit on the repeat run."""
    cfg = DecByzPGConfig(K=3, n_byz=1, attack="large_noise(sigma=10)",
                         aggregator="rfa", agreement="gda", kappa=1,
                         N=3, B=2, policy=TINY_TF)
    out = run_decbyzpg(ENV, cfg, 2)
    assert np.all(np.isfinite(out["returns"]))
    assert np.all(np.isfinite(out["diameter"]))
    n = engine.compile_count()
    again = run_decbyzpg(ENV, cfg, 2)
    assert engine.compile_count() == n
    np.testing.assert_array_equal(out["returns"], again["returns"])


def test_policy_spec_distinguishes_static_key():
    a = engine._algo("decbyzpg")
    s1, _, _ = engine.lane_split(DecByzPGConfig(), a.traced_fields)
    s2, _, _ = engine.lane_split(DecByzPGConfig(policy=TINY_TF),
                                 a.traced_fields)
    assert s1 != s2
