"""donation pass: forced-donation aliasing audit fires on undonatable
fixtures (a donated buffer with no same-shaped output) and on registry
drift, and every real site is clean."""

import jax
import jax.numpy as jnp

from repro.analysis.donation import Site, check_site, run, sites


def _rules(findings):
    return {f.rule for f in findings}


def test_undonatable_carry_flagged():
    # output is a scalar: none of the donated 256 input bytes can alias
    site = Site("fixture", "does/not/exist.py", (0,),
                lambda: (lambda x: x.sum(), (jnp.zeros((8, 8)),)),
                r"unused")
    findings = check_site(site)
    assert _rules(findings) & {"unusable-donation", "partial-alias"}
    for f in findings:
        assert "[fixture]" in f.message


def test_partially_aliasable_carry_flagged():
    # only the second tuple element comes back out; the first is dead
    # weight, so aliased bytes < donated bytes
    def fn(pair):
        a, b = pair
        return a.sum(), b * 2.0

    site = Site("fixture", "does/not/exist.py", (0,),
                lambda: (fn, ((jnp.zeros((64,)), jnp.zeros((64,))),)),
                r"unused")
    assert "partial-alias" in _rules(check_site(site))


def test_fully_aliasable_carry_clean():
    site = Site("fixture", "does/not/exist.py", (0,),
                lambda: (lambda x: x * 2.0 + 1.0, (jnp.zeros((32, 32)),)),
                r"unused")
    assert check_site(site) == []


def test_site_drift_flagged():
    def must_not_build():
        raise AssertionError("drifted site must not be compiled")

    site = Site("fixture", "src/repro/core/engine.py", (0,),
                must_not_build, r"THIS_PATTERN_IS_NOT_IN_ENGINE_PY")
    findings = check_site(site)
    assert _rules(findings) == {"site-drift"}


def test_site_registry_matches_sources():
    # the drift patterns alone (no compiles): every registered site's
    # donate_argnums still appear in its source file
    import re
    from repro.analysis.lint import repo_root

    for site in sites():
        text = (repo_root() / site.path).read_text()
        assert re.search(site.source_pattern, text), site.name


def test_real_sites_clean():
    assert run() == []
