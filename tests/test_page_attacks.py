"""PAGE estimator properties + message-level attack unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as atk
from repro.core.page import PageState, init_page, page_direction

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# PAGE on a noisy quadratic: variance reduction + convergence
# ---------------------------------------------------------------------------

def _noisy_grad(key, params, sigma=1.0):
    # f(x) = 0.5||x||^2, stochastic gradient x + noise
    return params + sigma * jax.random.normal(key, params.shape)


def test_page_small_step_low_variance():
    """After a tiny parameter move, the PAGE direction's deviation from the
    true gradient is far below the fresh-small-batch estimator's."""
    d = 50
    x = jnp.ones((d,))
    key = KEY
    page_errs, fresh_errs = [], []
    for s in range(200):
        key, k1, k2 = jax.random.split(key, 3)
        x_prev = x + 0.001 * jax.random.normal(k1, (d,))
        v_prev = x_prev  # exact gradient at prev (large batch limit)
        g_new = _noisy_grad(k2, x)
        g_old = _noisy_grad(k2, x_prev)     # SAME randomness (same batch)
        v = g_new - g_old + v_prev
        page_errs.append(float(jnp.sum((v - x) ** 2)))
        fresh_errs.append(float(jnp.sum((g_new - x) ** 2)))
    assert np.mean(page_errs) < 0.1 * np.mean(fresh_errs)


def test_page_direction_converges_on_quadratic():
    rng = np.random.default_rng(0)
    x = jnp.full((20,), 5.0)
    state = init_page(x)

    def grad_fn(params, batch):
        return params + 0.3 * batch

    key = KEY
    for t in range(300):
        key, k = jax.random.split(key)
        batch = jax.random.normal(k, x.shape)
        large = t == 0 or rng.random() < 0.2
        state = page_direction(grad_fn, x, state, batch, use_large=large)
        x = x - 0.1 * state.v
    assert float(jnp.linalg.norm(x)) < 1.0


# ---------------------------------------------------------------------------
# Attacks
# ---------------------------------------------------------------------------

def _setup(K=10, n_byz=3, d=6):
    honest = jax.random.normal(KEY, (K, d)) + 2.0
    mask = jnp.asarray(np.arange(K) < n_byz)
    return honest, mask


def test_avg_zero_makes_mean_zero():
    x, mask = _setup()
    out = atk.avg_zero(x, mask, KEY)
    np.testing.assert_allclose(jnp.mean(out, 0), 0.0, atol=1e-5)
    # honest rows untouched
    np.testing.assert_allclose(out[3:], x[3:])


def test_large_noise_magnitude():
    x, mask = _setup()
    out = atk.large_noise(x, mask, KEY, sigma=100.0)
    assert float(jnp.std(out[:3])) > 50
    np.testing.assert_allclose(out[3:], x[3:])


def test_sign_flip_directions():
    x, mask = _setup()
    out = atk.sign_flip(x, mask, KEY)
    hm = jnp.mean(x[3:], 0)
    for i in range(3):
        assert float(jnp.dot(out[i], hm)) < 0


def test_alie_stays_within_spread():
    x, mask = _setup(K=20, n_byz=4)
    out = atk.alie(x, mask, KEY, z=1.5)
    hm, hs = jnp.mean(x[4:], 0), jnp.std(x[4:], 0)
    assert bool(jnp.all(jnp.abs(out[0] - hm) <= 2.0 * hs + 1e-4))


def test_per_receiver_shapes_and_honest_consistency():
    x, mask = _setup(K=6, n_byz=2)
    fn = atk.per_receiver(atk.get_attack("large_noise"), K=6)
    msgs = fn(x, mask, KEY)
    assert msgs.shape == (6, 6, 6)
    # two receivers see different byz values but identical honest values
    assert not np.allclose(msgs[0, 0], msgs[1, 0])


def test_stacked_attacks_match_flat():
    """distributed.aggregation.attack_stacked == core.attacks on ravel."""
    from repro.distributed.aggregation import attack_stacked
    K, d = 8, 12
    x, mask = _setup(K=K, n_byz=2, d=d)
    tree = {"a": x[:, :5].reshape(K, 5), "b": x[:, 5:].reshape(K, 7)}
    out_tree = attack_stacked("avg_zero", tree, mask, KEY)
    flat = jnp.concatenate([out_tree["a"].reshape(K, -1),
                            out_tree["b"].reshape(K, -1)], axis=1)
    # per-leaf avg-zero == full-vector avg-zero (coordinate-wise op)
    want = atk.avg_zero(x, mask, KEY)
    np.testing.assert_allclose(flat, want, atol=1e-5)
