"""repro.obs telemetry layer (DESIGN.md §8).

The two contracts under test:

* zero-overhead-off: with ``telemetry=False`` (the default) the built
  programs are the exact seed programs — no ``debug_callback`` in the
  jaxpr, identical compiled-loop cache keys, bit-identical histories;
* forensics-on: with ``telemetry=True`` a run under an attack yields the
  tap stream (per-iteration Δ₂ included), stacked ``grad_norm`` /
  ``rejected`` histories, and an ``aggregator_confusion`` tally whose
  precision/recall surface in ``Experiment.summary()``.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine
from repro.core.aggregators import rejection_mask, suspicion_scores
from repro.core.decbyzpg import (DecByzPGConfig, build_decbyzpg_loop,
                                 run_decbyzpg)
from repro.core.engine import Experiment
from repro.kernels import dispatch
from repro.rl.envs import make_env


def _cfg(**kw):
    base = dict(K=4, n_byz=1, attack="sign_flip", aggregator="krum",
                N=4, B=2, kappa=2, hidden=(4,))
    base.update(kw)
    return DecByzPGConfig(**base)


def _loop_jaxpr(cfg, T=3):
    env = make_env("cartpole(horizon=10)")
    loop = build_decbyzpg_loop(env, cfg, T)
    ks = engine.seed_keys(0)
    from repro.core.decbyzpg import init_decbyzpg_carry
    carry = init_decbyzpg_carry(env, cfg, ks.init)
    return jax.make_jaxpr(loop)(*carry, jax.random.split(ks.loop, T),
                                ks.coin)


def _has_primitive(jaxpr, name: str) -> bool:
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name == name:
            return True
        for v in eqn.params.values():
            if hasattr(v, "jaxpr") and _has_primitive(v, name):
                return True
    return False


class TestZeroOverheadOff:
    def test_off_jaxpr_has_no_debug_callback(self):
        assert not _has_primitive(_loop_jaxpr(_cfg()), "debug_callback")

    def test_on_jaxpr_has_debug_callback(self):
        assert _has_primitive(_loop_jaxpr(_cfg(telemetry=True)),
                              "debug_callback")

    def test_histories_bit_identical_on_off(self):
        env = make_env("cartpole(horizon=10)")
        off = run_decbyzpg(env, _cfg(seed=2), 4)
        on = run_decbyzpg(env, _cfg(seed=2, telemetry=True), 4)
        np.testing.assert_array_equal(off["returns"], on["returns"])
        np.testing.assert_array_equal(off["diameter"], on["diameter"])

    def test_off_run_reuses_one_cache_entry(self):
        env = make_env("cartpole(horizon=10)")
        engine.clear_cache()
        run_decbyzpg(env, _cfg(), 3)
        n_off = engine.compile_count()
        run_decbyzpg(env, _cfg(seed=5), 3)      # seed is data, not program
        assert engine.compile_count() == n_off
        # telemetry is static: the on path is a *separate* entry and the
        # off entry is untouched
        run_decbyzpg(env, _cfg(telemetry=True), 3)
        assert engine.compile_count() == n_off + 1
        run_decbyzpg(env, _cfg(), 3)
        assert engine.compile_count() == n_off + 1

    def test_taps_silent_without_recorder_noise(self, capsys):
        # default recorder only prints the progress stream: a telemetry
        # run must not spam stdout through the tap streams
        env = make_env("cartpole(horizon=10)")
        run_decbyzpg(env, _cfg(telemetry=True, seed=7), 3)
        assert capsys.readouterr().out == ""


class TestForensicsOn:
    def test_jsonl_stream_under_sign_flip(self, tmp_path):
        env = make_env("cartpole(horizon=10)")
        path = str(tmp_path / "metrics.jsonl")
        with obs.telemetry(obs.JsonlSink(path)):
            out = run_decbyzpg(env, _cfg(telemetry=True, seed=4), 5)
        taps = [json.loads(l) for l in open(path)
                if json.loads(l)["stream"] == "decbyzpg"]
        assert len(taps) == 5
        assert all("diameter" in r and "grad_norm" in r
                   and "rejected" in r for r in taps)
        # the stream replays the stacked histories, in order
        np.testing.assert_allclose([r["diameter"] for r in taps],
                                   np.asarray(out["diameter"]), rtol=1e-6)

    def test_confusion_tally_and_summary_recall(self):
        # sign_flip rescales by -4x: krum reliably rejects the Byzantine
        # agent, so recall on the true set must be high
        exp = Experiment(algo="decbyzpg", env="cartpole(horizon=10)", T=4,
                         seeds=2, K=4, n_byz=1, attack="sign_flip",
                         aggregator="krum", N=4, B=2, kappa=2,
                         hidden=(4,), telemetry=True)
        summ = exp.summary()["base"]
        assert 0.0 <= summ["aggregator_precision"] <= 1.0
        assert summ["aggregator_recall"] >= 0.5
        res = exp.run()
        conf = next(iter(res.items()))[1]["aggregator_confusion"]
        assert conf["tp"] + conf["fn"] == conf["rounds"] * conf["n_byz"]

    def test_summary_without_telemetry_has_no_forensics(self):
        exp = Experiment(algo="decbyzpg", env="cartpole(horizon=10)", T=3,
                         seeds=2, K=3, n_byz=1, attack="sign_flip",
                         N=4, B=2, kappa=2, hidden=(4,))
        summ = exp.summary()["base"]
        assert "aggregator_precision" not in summ

    def test_confusion_tally_counts(self):
        rej = np.array([[True, False, False], [False, False, True]])
        c = obs.confusion_tally(rej, n_byz=1)
        assert (c["tp"], c["fp"], c["fn"], c["tn"]) == (1, 1, 1, 3)
        assert c["precision"] == 0.5 and c["recall"] == 0.5
        z = obs.confusion_tally(np.zeros((4, 3), bool), n_byz=0)
        assert z["precision"] == 0.0 and z["recall"] == 0.0


class TestRejectionMask:
    def _stack(self):
        # agent 0 is a gross outlier of an otherwise tight cluster
        x = np.ones((5, 8), np.float32)
        x += np.arange(5, dtype=np.float32)[:, None] * 1e-3
        x[0] = 100.0
        return jnp.asarray(x)

    @pytest.mark.parametrize("spec", ["krum", "trimmed_mean", "rfa",
                                      "cwmed"])
    def test_outlier_rejected(self, spec):
        mask = np.asarray(rejection_mask(spec, self._stack(), 1))
        assert mask.tolist() == [True, False, False, False, False]

    def test_cardinality_pinned_to_n_byz(self):
        mask = np.asarray(rejection_mask("krum", self._stack(), 2))
        assert int(mask.sum()) == 2 and bool(mask[0])

    def test_n_byz_zero_rejects_nobody(self):
        mask = np.asarray(rejection_mask("krum", self._stack(), 0))
        assert not mask.any()

    def test_scores_jit_and_vmap(self):
        x = self._stack()
        s = jax.jit(lambda a: suspicion_scores("trimmed_mean", a, 1))(x)
        assert s.shape == (5,) and float(s[0]) == max(map(float, s))
        batched = jax.vmap(lambda a: rejection_mask("krum", a, 1))(
            jnp.stack([x, x]))
        assert np.asarray(batched).shape == (2, 5)


class TestHostPlane:
    def test_ring_buffer_bounded(self):
        rb = obs.RingBuffer(capacity=3)
        for i in range(5):
            rb.append({"i": i})
        assert len(rb) == 3 and rb.dropped == 2
        assert rb.latest()["i"] == 4

    def test_capture_and_streams(self):
        with obs.capture() as sink:
            obs.record("a", x=1)
            obs.record("b", x=2)
        assert {r["stream"] for r in sink.records} == {"a", "b"}
        with obs.capture("a") as sink:
            obs.record("a", x=1)
            obs.record("b", x=2)
        assert [r["stream"] for r in sink.records] == ["a"]

    def test_telemetry_scope_restores_enabled(self):
        assert not obs.enabled()
        with obs.telemetry():
            assert obs.enabled()
        assert not obs.enabled()

    def test_progress_prints(self, capsys):
        obs.progress("hello world", step=3)
        assert "hello world" in capsys.readouterr().out

    def test_stdout_sink_filters_streams(self, capsys):
        s = obs.StdoutProgressSink()
        s.emit({"stream": "decbyzpg", "t": 0})
        assert capsys.readouterr().out == ""
        s.emit({"stream": "progress", "message": "msg"})
        assert "msg" in capsys.readouterr().out
        everything = obs.StdoutProgressSink(streams=None)
        everything.emit({"stream": "decbyzpg", "t": 0})
        assert "decbyzpg" in capsys.readouterr().out

    def test_jsonl_sink_plain_python(self, tmp_path):
        path = str(tmp_path / "x.jsonl")
        sink = obs.JsonlSink(path)
        sink.emit({"stream": "s", "arr": np.arange(3),
                   "scalar": np.float32(1.5)})
        sink.close()
        rec = json.loads(open(path).read())
        assert rec["arr"] == [0, 1, 2] and rec["scalar"] == 1.5

    def test_engine_cache_events(self):
        engine.clear_cache()
        with obs.capture("engine.cache") as sink:
            engine.compiled("k1", lambda: "fn")
            engine.compiled("k1", lambda: "fn")
        events = [r["event"] for r in sink.records]
        assert events == ["miss", "hit"]

    def test_tracer_chrome_format(self, tmp_path):
        tr = obs.Tracer()
        with tr.span("phase", n=3):
            pass
        tr.instant("marker")
        doc = tr.to_chrome()
        assert {e["ph"] for e in doc["traceEvents"]} == {"X", "i"}
        x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
        assert x["name"] == "phase" and x["dur"] >= 0 \
            and x["args"] == {"n": 3}
        path = str(tmp_path / "trace.json")
        tr.to_chrome(path)
        assert json.load(open(path))["displayTimeUnit"] == "ms"

    def test_host_span_noop_when_disabled(self):
        tr = obs.get_tracer()
        tr.clear()
        with obs.host_span("nope"):
            pass
        assert tr.events == []
        with obs.telemetry():
            with obs.host_span("yes"):
                pass
        assert [e["name"] for e in tr.events] == ["yes"]
        tr.clear()


class TestDispatchCounters:
    def test_resolve_backend_tallies(self):
        from repro.kernels.dispatch import get_kernel
        k = get_kernel("krum_score")
        dispatch.reset_dispatch_counts()
        x = jnp.ones((4, 8))
        k(x, 2)
        counts = dispatch.dispatch_counts()
        assert sum(counts.values()) == 1
        ((name, backend, reason),) = counts
        assert name == "krum_score" and backend in dispatch.BACKENDS
        assert reason in ("auto", "auto_jnp_below")
        k(x, 2, backend="jnp")
        assert dispatch.dispatch_counts()[
            ("krum_score", "jnp", "call")] == 1
        with dispatch.use_backend("jnp"):
            k(x, 2)
        assert dispatch.dispatch_counts()[
            ("krum_score", "jnp", "global")] == 1
        dispatch.reset_dispatch_counts()
        assert dispatch.dispatch_counts() == {}

    def test_manifest_includes_counters(self):
        m = obs.build_manifest(extra={"note": "t"})
        assert m["jax_version"] == jax.__version__
        assert "kernel_dispatch_counts" in m
        assert m["compiled_loop_cache_entries"] == engine.compile_count()
        assert m["note"] == "t"
