"""Integration tests: ByzPG / DecByzPG on CartPole, federated LLM training
resilience, stacked vs flat aggregation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.byzpg import ByzPGConfig, run_byzpg
from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
from repro.distributed.fed_trainer import (FedConfig, fed_train_step,
                                           init_fed_state)
from repro.rl.envs import make_cartpole

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_byzpg_learns_cartpole():
    env = make_cartpole(horizon=100)
    out = run_byzpg(env, ByzPGConfig(K=5, N=20, B=4, eta=5e-3, seed=1),
                    T=25)
    assert np.mean(out["returns"][-5:]) > np.mean(out["returns"][:3]) + 8


@pytest.mark.slow
def test_byzpg_robust_vs_mean_under_large_noise():
    env = make_cartpole(horizon=80)
    kw = dict(K=7, n_byz=2, attack="large_noise", N=15, B=3, eta=5e-3,
              seed=2)
    robust = run_byzpg(env, ByzPGConfig(aggregator="rfa", **kw), T=18)
    naive = run_byzpg(env, ByzPGConfig(aggregator="mean", **kw), T=18)
    assert np.mean(robust["returns"][-5:]) > np.mean(naive["returns"][-5:])


@pytest.mark.slow
def test_decbyzpg_agreement_keeps_agents_synced():
    env = make_cartpole(horizon=60)
    # bucketed RFA uses per-agent randomness, so without agreement the
    # agents' parameters drift apart; Avg-Agree_4 keeps them synced.
    out = run_decbyzpg(env, DecByzPGConfig(
        K=5, n_byz=1, attack="large_noise", aggregator="rfa", kappa=4,
        N=10, B=2, eta=5e-3, seed=3), T=10)
    assert max(out["diameter"][2:]) < 1.0
    out_nok = run_decbyzpg(env, DecByzPGConfig(
        K=5, n_byz=1, attack="large_noise", aggregator="rfa", kappa=0,
        N=10, B=2, eta=5e-3, seed=3), T=10)
    assert max(out["diameter"]) < 0.1 * max(out_nok["diameter"])


def test_fed_llm_robust_agg_resists_avg_zero():
    """Honest-loss under avg_zero: robust aggregation keeps improving,
    naive mean stalls (gradient sum driven to zero)."""
    cfg = reduced(get_config("llama3_2_1b"))
    K = 4
    batch = {"tokens": jax.random.randint(KEY, (K, 2, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(KEY, (K, 2, 16), 0,
                                          cfg.vocab_size)}
    mask = jnp.array([True, False, False, False])

    def run(agg):
        fed = FedConfig(aggregator=agg, kappa=2, n_byz=1,
                        attack="avg_zero", lr=2e-3)
        state = init_fed_state(cfg, fed, K, KEY)
        losses = []
        for i in range(8):
            state, m = fed_train_step(cfg, fed, state, batch, mask,
                                      jax.random.PRNGKey(i), large=True)
            losses.append(float(m["loss"]))
        return losses

    robust = run("rfa")
    naive = run("mean")
    assert robust[-1] < robust[0] - 0.05          # robust improves
    assert (robust[0] - robust[-1]) > 2.0 * (naive[0] - naive[-1])


def test_fed_page_small_step_runs_and_improves():
    cfg = reduced(get_config("qwen2_5_3b"))
    K = 2
    fed = FedConfig(aggregator="mean", kappa=0, lr=2e-3)
    state = init_fed_state(cfg, fed, K, KEY)
    batch = {"tokens": jax.random.randint(KEY, (K, 2, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(KEY, (K, 2, 16), 0,
                                          cfg.vocab_size)}
    mask = jnp.zeros((K,), bool)
    losses = []
    for i, large in enumerate([True, False, False, False, False, False]):
        state, m = fed_train_step(cfg, fed, state, batch, mask,
                                  jax.random.PRNGKey(i), large=large)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_stacked_aggregators_match_core_on_matrices():
    """distributed.agg (stacked trees) == core.agg (flat (K,d)) for rfa,
    trimmed_mean, krum on equivalent inputs."""
    from repro.core import aggregators as core_agg
    from repro.distributed import aggregation as dist_agg
    K, d = 9, 30
    x = jax.random.normal(KEY, (K, d))
    x = x.at[:2].set(20.0)
    tree = {"a": x[:, :13], "b": x[:, 13:].reshape(K, 17)}

    got = dist_agg.agg_trimmed_mean(tree, n_byz=2)
    flat = jnp.concatenate([got["a"][0], got["b"][0]])
    want = core_agg.trimmed_mean(x, n_byz=2)
    np.testing.assert_allclose(flat, want, atol=1e-5)

    got = dist_agg.agg_krum(tree, n_byz=2)
    flat = jnp.concatenate([got["a"][0], got["b"][0]])
    want = core_agg.krum(x, n_byz=2)
    np.testing.assert_allclose(flat, want, atol=1e-5)

    got = dist_agg.agg_rfa(tree, n_iter=32)
    flat = jnp.concatenate([got["a"][0], got["b"][0]])
    want = core_agg.rfa(x, n_iter=32)
    np.testing.assert_allclose(flat, want, atol=1e-2, rtol=1e-2)
