"""Substrate tests: optimizer, data pipeline, checkpoint, tree utils."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.core.tree import ravel, stack_ravel, unstack_unravel
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.optimizers import adam, cosine_schedule, sgd

KEY = jax.random.PRNGKey(0)


def test_adam_converges_quadratic():
    opt = adam(0.1, maximize=False)
    params = {"w": jnp.full((8,), 5.0)}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": params["w"]}
        params, state = opt.update(g, state, params)
    assert float(jnp.linalg.norm(params["w"])) < 0.1


def test_adam_maximize_ascends():
    opt = adam(0.05, maximize=True)
    params = jnp.zeros((4,))
    state = opt.init(params)
    for _ in range(50):
        g = 1.0 - params          # maximize -0.5(x-1)^2
        params, state = opt.update(g, state, params)
    np.testing.assert_allclose(params, 1.0, atol=0.1)


def test_sgd_momentum_shapes():
    opt = sgd(0.1, momentum=0.9)
    params = {"a": jnp.ones((3, 3)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, _ = opt.update(g, state, params)
    assert p2["a"].shape == (3, 3)


def test_cosine_schedule_monotone_segments():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    vals = [float(lr(jnp.asarray(s))) for s in range(0, 100, 5)]
    assert vals[0] < vals[2]           # warmup rises
    assert vals[-1] < vals[3]          # decays after warmup
    assert vals[-1] >= 0.1 - 1e-6      # min_frac floor


def test_pipeline_deterministic_and_sharded_by_agent():
    cfg = DataConfig(vocab_size=100, seq_len=16, per_agent_batch=2,
                     n_agents=3, seed=7)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch(5), p2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (3, 2, 16)
    # labels shifted by one within the stream
    np.testing.assert_array_equal(b1["tokens"][..., 1:],
                                  b1["labels"][..., :-1])
    # different steps/agents differ
    assert not np.array_equal(p1.batch(6)["tokens"], b1["tokens"])
    assert not np.array_equal(b1["tokens"][0], b1["tokens"][1])


def test_pipeline_prefix_embeds():
    cfg = DataConfig(vocab_size=50, seq_len=8, per_agent_batch=2,
                     n_agents=2, n_prefix_embeds=4, d_model=16)
    b = TokenPipeline(cfg).batch(0)
    assert b["prefix_embeds"].shape == (2, 2, 4, 16)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"x": jnp.arange(6.0).reshape(2, 3),
            "nested": {"y": jnp.ones((4,), jnp.int32)}}
    path = os.path.join(tmp_path, "ck.npz")
    save(tree, path)
    out = restore(jax.tree.map(lambda l: jax.ShapeDtypeStruct(
        l.shape, l.dtype), tree), path)
    np.testing.assert_array_equal(out["x"], tree["x"])
    np.testing.assert_array_equal(out["nested"]["y"], tree["nested"]["y"])


def test_ravel_stack_consistency():
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((2, 4))}
    mat = stack_ravel(tree)               # K=2 agents
    assert mat.shape == (2, 7)
    template = {"a": jnp.zeros((3,)), "b": jnp.zeros((4,))}
    back = unstack_unravel(mat, template)
    np.testing.assert_array_equal(back["a"], tree["a"])
    # row k equals ravel of agent k's tree
    vec0, _ = ravel({"a": tree["a"][0], "b": tree["b"][0]})
    np.testing.assert_array_equal(mat[0], vec0)
