"""Robust aggregation (paper Def. 1 / App. A.2) unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg


KEY = jax.random.PRNGKey(0)


def honest_byz_inputs(K=13, n_byz=3, d=20, spread=0.1, byz_val=50.0,
                      key=KEY):
    x = spread * jax.random.normal(key, (K, d))
    x = x.at[:n_byz].set(byz_val)
    honest_mean = jnp.mean(x[n_byz:], axis=0)
    return x, honest_mean


def test_mean_not_robust():
    x, hm = honest_byz_inputs()
    assert jnp.linalg.norm(agg.mean(x) - hm) > 1.0


@pytest.mark.parametrize("name", ["krum", "rfa", "cwmed", "trimmed_mean"])
def test_robust_aggregators_resist_large_outliers(name):
    x, hm = honest_byz_inputs()
    f = agg.get_aggregator(name, K=13, n_byz=3)
    out = f(x, jax.random.PRNGKey(1))
    assert jnp.linalg.norm(out - hm) < 1.0, name


def test_krum_selects_an_honest_vector():
    x, _ = honest_byz_inputs(K=9, n_byz=2)
    out = agg.krum(x, n_byz=2)
    dists = jnp.linalg.norm(x - out, axis=1)
    assert int(jnp.argmin(dists)) >= 2          # not a Byzantine row


def test_rfa_is_geometric_median_1d():
    # geometric median in 1D = median
    x = jnp.array([[1.0], [2.0], [3.0], [4.0], [100.0]])
    out = agg.rfa(x, n_iter=64)
    assert abs(float(out[0]) - 3.0) < 0.1


def test_trimmed_mean_exact():
    x = jnp.array([[0.0, 5.0], [1.0, 6.0], [2.0, 7.0], [3.0, 8.0],
                   [100.0, -100.0]])
    out = agg.trimmed_mean(x, n_byz=1)
    np.testing.assert_allclose(out, [2.0, 6.0], atol=1e-6)


def test_bucketing_reduces_to_inner_on_full_bucket():
    x, hm = honest_byz_inputs(K=12, n_byz=0, byz_val=0.0)
    out = agg.bucketing(agg.rfa, x, jax.random.PRNGKey(2), bucket_size=1)
    np.testing.assert_allclose(out, agg.rfa(x), atol=1e-5)


def test_bucketing_forwards_key_to_inner():
    """A key-consuming inner aggregator (e.g. DnC-style subsampling) must
    receive a PRNG key from bucketing, not silently get none."""
    x, _ = honest_byz_inputs(K=8, n_byz=0, byz_val=0.0)
    keys_seen = []

    def inner(means, key=None):
        assert key is not None
        keys_seen.append(np.asarray(key))
        return jnp.mean(means, axis=0)

    outer_key = jax.random.PRNGKey(7)
    agg.bucketing(inner, x, outer_key, bucket_size=2)
    # the forwarded key is a fresh split, never the raw outer key
    assert not np.array_equal(keys_seen[0], np.asarray(outer_key))


def test_robust_aggregation_definition_bound():
    """Empirical check of Def. 1: E||Agg(x) - honest_mean||^2 bounded by
    C*alpha/(|H|(|H|-1)) * sum of pairwise honest distances (C_ra ~ O(1))."""
    K, n_byz, d = 13, 3, 8
    errs, bounds = [], []
    for seed in range(10):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (K, d))
        x = x.at[:n_byz].set(30.0)
        hm = jnp.mean(x[n_byz:], axis=0)
        f = agg.get_aggregator("rfa", K=K, n_byz=n_byz)
        out = f(x, k2)
        errs.append(float(jnp.sum((out - hm) ** 2)))
        h = x[n_byz:]
        pair = agg.pairwise_sq_dists(h)
        nh = K - n_byz
        bounds.append(float((n_byz / K) / (nh * (nh - 1)) * jnp.sum(pair)))
    C_ra = np.mean(errs) / max(np.mean(bounds), 1e-12)
    assert C_ra < 60.0, f"C_ra estimate too large: {C_ra}"


def test_aggregators_no_byzantine_close_to_mean():
    x = 0.1 * jax.random.normal(KEY, (8, 16))
    for name in ["krum", "rfa", "trimmed_mean", "cwmed"]:
        f = agg.get_aggregator(name, K=8, n_byz=0)
        out = f(x, jax.random.PRNGKey(3))
        # krum returns a single input vector, so allow the honest spread
        tol = 0.6 if name == "krum" else 0.25
        assert jnp.linalg.norm(out - jnp.mean(x, 0)) < tol, name
