"""retrace pass: cache-key hygiene rules fire on broken config fixtures
(unhashable static field, per-instance default, missing traced field,
lane-split leak), and the real registry + the dynamic compile-count gate
are clean."""

import dataclasses

import pytest

from repro.analysis.retrace import (audit_compiles, audit_static,
                                    audit_static_config)
from repro.core import engine


def _rules(findings):
    return {f.rule for f in findings}


@dataclasses.dataclass(frozen=True)
class _GoodCfg:
    seed: int = 0
    eta: float = 1e-2
    K: int = 4


@dataclasses.dataclass(frozen=True)
class _UnhashableCfg:
    seed: int = 0
    hidden: list = dataclasses.field(default_factory=lambda: [16, 16])


@dataclasses.dataclass(frozen=True)
class _UnstableCfg:
    seed: int = 0
    tag: object = dataclasses.field(default_factory=object)


@dataclasses.dataclass(frozen=True)
class _NoDefaultCfg:
    seed: int
    eta: float


def test_unhashable_static_field_flagged():
    findings = audit_static_config("fixture", _UnhashableCfg, ())
    assert _rules(findings) == {"unhashable-static"}
    assert findings[0].line > 0 and findings[0].path.endswith(
        "test_analysis_retrace.py")


def test_unstable_static_key_flagged():
    findings = audit_static_config("fixture", _UnstableCfg, ())
    assert _rules(findings) == {"unstable-static-key"}


def test_default_config_must_construct():
    findings = audit_static_config("fixture", _NoDefaultCfg, ())
    assert _rules(findings) == {"default-config"}


def test_missing_traced_field_flagged():
    findings = audit_static_config("fixture", _GoodCfg,
                                   ("eta", "does_not_exist"))
    assert _rules(findings) == {"traced-field-missing"}


def test_lane_split_leak_flagged(monkeypatch):
    # regression guard: if engine.lane_split stopped blanking traced
    # fields, every swept value would compile its own program
    def broken_lane_split(cfg, traced_fields):
        traced = tuple(float(getattr(cfg, n)) for n in traced_fields)
        return engine.static_key(cfg), traced_fields, traced

    monkeypatch.setattr(engine, "lane_split", broken_lane_split)
    findings = audit_static_config("fixture", _GoodCfg, ("eta",))
    assert _rules(findings) == {"traced-leaks-into-static"}


def test_clean_fixture_config():
    assert audit_static_config("fixture", _GoodCfg, ("eta",)) == []


def test_registry_configs_clean():
    assert audit_static() == []


@pytest.mark.slow
def test_compile_count_gate():
    assert audit_compiles() == []
