"""Sweep service tests (DESIGN.md §12): window slicing, windowed-vs-
one-shot bit-identity through SweepRunner, kill-and-resume from the
manifest, resume compile accounting, and manifest mismatch reporting."""
import numpy as np
import pytest

from repro.core import engine
from repro.core.engine import ScenarioGrid, run_grid
from repro.rl.envs import make_cartpole
from repro.sweep import SweepError, SweepMismatch, SweepRunner

ENV_SPEC = "cartpole(horizon=20)"
ENV = make_cartpole(horizon=20)
T = 6

DEC_KW = dict(K=3, n_byz=1, N=4, B=2, kappa=2, hidden=(8,))
DEC_AXES = {"eta": (1e-2, 5e-3),
            "attack": ("none", "large_noise(sigma=10)")}


def _assert_results_equal(res, ref):
    """res: ExperimentResult from the sweep; ref: run_grid dict."""
    assert set(map(tuple, res.keys())) == set(map(tuple, ref.keys()))
    for scn in ref:
        got, want = res[tuple(scn)], ref[scn]
        assert set(got) == set(want)
        for k in ("returns", "samples"):
            np.testing.assert_array_equal(got[k], want[k])
        assert got["final_return_mean"] == want["final_return_mean"]


# ---------------------------------------------------------------------------
# window_slices
# ---------------------------------------------------------------------------


def test_window_slices_cover_and_two_widths():
    for T_, W in ((6, 1), (6, 3), (7, 3), (50, 7), (5, 5)):
        slices = engine.window_slices(T_, W)
        assert len(slices) == W
        assert slices[0][0] == 0 and slices[-1][1] == T_
        # contiguous, and at most two distinct widths (remainder leading)
        for (_, a_stop), (b_start, _) in zip(slices, slices[1:]):
            assert a_stop == b_start
        widths = sorted({stop - start for start, stop in slices})
        assert len(widths) <= 2
        if len(widths) == 2:
            assert widths[1] - widths[0] == 1


def test_window_slices_rejects_bad_counts():
    with pytest.raises(ValueError, match="windows"):
        engine.window_slices(5, 0)
    with pytest.raises(ValueError, match="windows"):
        engine.window_slices(5, 6)


# ---------------------------------------------------------------------------
# Windowed == one-shot (in-memory sweeps)
# ---------------------------------------------------------------------------


def test_sweep_windowed_matches_run_grid_decbyzpg():
    """A 3-window sweep over honest + attacked lanes reproduces the
    one-shot lane-batched grid bit for bit (the window programs replay
    the identical per-seed key stream)."""
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1), axes=DEC_AXES), T,
                   algo="decbyzpg", **DEC_KW)
    res = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                      axes=DEC_AXES, windows=3, **DEC_KW).run()
    _assert_results_equal(res, ref)
    for scn in ref:
        np.testing.assert_array_equal(res[tuple(scn)]["theta"],
                                      ref[scn]["theta"])


def test_sweep_windowed_matches_run_grid_byzpg():
    axes = {"eta": (1e-2, 2e-2)}
    kw = dict(K=3, n_byz=1, attack="sign_flip", N=4, B=2, hidden=(8,))
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1), axes=axes), T,
                   algo="byzpg", **kw)
    res = SweepRunner(algo="byzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                      axes=axes, windows=2, **kw).run()
    _assert_results_equal(res, ref)


def test_sweep_single_window_matches_run_grid():
    """windows=1 still routes through the windowed programs and matches."""
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1, 2),
                                     axes={"eta": (1e-2,)}), T,
                   algo="decbyzpg", attack="sign_flip", **DEC_KW)
    res = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=3,
                      axes={"eta": (1e-2,)}, windows=1,
                      attack="sign_flip", **DEC_KW).run()
    _assert_results_equal(res, ref)


# ---------------------------------------------------------------------------
# Kill-and-resume through the sweep directory
# ---------------------------------------------------------------------------


def test_sweep_kill_and_resume_bit_identical(tmp_path):
    """Crash simulation: one window executes, the process 'dies', a fresh
    runner resumes from the manifest — and the stitched result equals the
    uninterrupted one-shot grid exactly, attacked lanes included."""
    out = str(tmp_path / "sweep")
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1), axes=DEC_AXES), T,
                   algo="decbyzpg", **DEC_KW)
    first = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                        axes=DEC_AXES, windows=3, out_dir=out, **DEC_KW)
    assert first.run(max_windows=1) is None      # preempted mid-sweep
    # a fresh runner reconstructed purely from the manifest
    res = SweepRunner.resume(out).run()
    _assert_results_equal(res, ref)
    assert (tmp_path / "sweep" / "summary.json").exists()


def test_sweep_kill_and_resume_byzpg(tmp_path):
    out = str(tmp_path / "sweep")
    axes = {"eta": (1e-2, 2e-2)}
    kw = dict(K=3, n_byz=1, attack="large_noise(sigma=10)", N=4, B=2,
              hidden=(8,))
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1), axes=axes), T,
                   algo="byzpg", **kw)
    first = SweepRunner(algo="byzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                        axes=axes, windows=3, out_dir=out, **kw)
    assert first.run(max_windows=2) is None
    _assert_results_equal(SweepRunner.resume(out).run(), ref)


def test_sweep_resume_skips_completed_groups(tmp_path):
    """Resuming runs only the missing lane groups: a fully committed
    group reloads its artifacts with zero new compiles and zero
    dispatches; only the never-started group builds programs."""
    out = str(tmp_path / "sweep")
    # two lane groups: the attack *name* differs, so the static
    # signatures split (unlike a traced sigma sweep)
    axes = {"attack": ("none", "sign_flip")}
    W = 2
    runner = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T,
                         seeds=(0, 1), axes=axes, windows=W,
                         out_dir=out, **DEC_KW)
    assert runner.run(max_windows=W) is None    # group 0 done, group 1 not
    engine.clear_cache()
    res = SweepRunner.resume(out).run()
    # group 1 compiled its init + its (single-width) window program;
    # group 0 was reloaded from disk without touching the engine
    assert engine.compile_count() == 2
    ref = run_grid(ENV, ScenarioGrid(seeds=(0, 1), axes=axes), T,
                   algo="decbyzpg", **DEC_KW)
    _assert_results_equal(res, ref)


def test_sweep_completed_resume_compiles_nothing(tmp_path):
    """Re-running a finished sweep is a pure reload: the engine cache
    gains no entries and the result still matches."""
    out = str(tmp_path / "sweep")
    runner = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T,
                         seeds=(0, 1), axes=DEC_AXES, windows=2,
                         out_dir=out, **DEC_KW)
    first = runner.run()
    assert first is not None
    engine.clear_cache()
    res = SweepRunner.resume(out).run()
    assert engine.compile_count() == 0
    _assert_results_equal(res, {scn: first[tuple(scn)]
                                for scn in first.keys()})


# ---------------------------------------------------------------------------
# Manifest validation + runner argument errors
# ---------------------------------------------------------------------------


def test_sweep_manifest_mismatch_names_fields(tmp_path):
    out = str(tmp_path / "sweep")
    SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                axes={"eta": (1e-2,)}, windows=2, out_dir=out,
                **DEC_KW).run(max_windows=1)
    clash = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T + 2,
                        seeds=(0, 1, 2), axes={"eta": (1e-2,)},
                        windows=2, out_dir=out, **DEC_KW)
    with pytest.raises(SweepMismatch) as ei:
        clash.run()
    msg = str(ei.value)
    assert "meta.T" in msg and "meta.seeds" in msg
    assert "window_slices" in msg


def test_sweep_resume_recorded_override_requires_hook(tmp_path):
    out = str(tmp_path / "sweep")
    hook = lambda cfg: cfg                                  # noqa: E731
    SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0,),
                axes={"eta": (1e-2,)}, windows=2, out_dir=out,
                override=hook, **DEC_KW).run(max_windows=1)
    with pytest.raises(SweepError, match="override"):
        SweepRunner.resume(out)
    res = SweepRunner.resume(out, override=hook).run()
    assert res is not None


def test_sweep_rejects_unknown_mode_and_non_persistable_axis():
    with pytest.raises(SweepError, match="mode"):
        SweepRunner(mode="galaxy")
    bad = SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0,),
                      axes={"eta": (1e-2,)}, windows=1,
                      hidden=(8,), K=3, N=4, B=2,
                      probe=object())
    with pytest.raises(SweepError, match="persist"):
        bad._meta()


# ---------------------------------------------------------------------------
# Telemetry plane: sweep.window / sweep.partial stream through repro.obs
# ---------------------------------------------------------------------------


def test_sweep_streams_window_and_partial_records(tmp_path):
    from repro import obs
    with obs.capture() as sink:
        SweepRunner(algo="decbyzpg", env=ENV_SPEC, T=T, seeds=(0, 1),
                    axes={"eta": (1e-2, 5e-3)}, windows=3,
                    out_dir=str(tmp_path / "s"), **DEC_KW).run()
    windows = [r for r in sink.records if r["stream"] == "sweep.window"]
    partials = [r for r in sink.records if r["stream"] == "sweep.partial"]
    assert [w["window"] for w in windows] == [0, 1, 2]
    assert windows[-1]["t_done"] == T
    assert len(partials) == 2               # one per scenario in the group
    assert all(np.isfinite(p["final_return_mean"]) for p in partials)
