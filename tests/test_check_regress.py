"""Unit tests for the CI perf-regression gate (benchmarks/check_regress)."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.check_regress import main  # noqa: E402


def _kernels_doc(us_by_key):
    rows = [{"kernel": k, "backend": b, "K": K, "P": P, "D": D,
             "us_per_call": us}
            for (k, b, K, P, D), us in us_by_key.items()]
    return {"bench": "kernels", "smoke": False, "rows": rows}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


BASE = {("rfa", "jnp", 8, 4, 512): 1000.0,
        ("trimmed_mean", "jnp", 8, 4, 512): 400.0,
        ("krum_score", "jnp", 8, 4, 512): 10.0}     # below --min-us floor


def test_passes_within_tolerance(tmp_path):
    cur = {k: v * 1.5 for k, v in BASE.items()}
    argv = ["--pair",
            f"{_write(tmp_path, 'cur.json', _kernels_doc(cur))}:"
            f"{_write(tmp_path, 'base.json', _kernels_doc(BASE))}"]
    assert main(argv) == 0


def test_fails_on_2x_slowdown(tmp_path):
    cur = dict(BASE)
    cur[("rfa", "jnp", 8, 4, 512)] = 2100.0        # injected 2.1x
    argv = ["--pair",
            f"{_write(tmp_path, 'cur.json', _kernels_doc(cur))}:"
            f"{_write(tmp_path, 'base.json', _kernels_doc(BASE))}"]
    assert main(argv) == 1
    assert main(argv + ["--tol", "3.0"]) == 0      # tolerance configurable


def test_min_us_floor_skips_micro_entries(tmp_path):
    cur = dict(BASE)
    cur[("krum_score", "jnp", 8, 4, 512)] = 90.0   # 9x, but base is 10us
    argv = ["--pair",
            f"{_write(tmp_path, 'cur.json', _kernels_doc(cur))}:"
            f"{_write(tmp_path, 'base.json', _kernels_doc(BASE))}"]
    assert main(argv) == 0
    assert main(argv + ["--min-us", "5"]) == 1


def test_absent_keys_and_missing_baseline_skipped(tmp_path):
    cur = dict(BASE)
    cur[("gossip_reduce", "jnp", 16, 8, 4096)] = 1e9   # no baseline entry
    cur_path = _write(tmp_path, "cur.json", _kernels_doc(cur))
    base_path = _write(tmp_path, "base.json", _kernels_doc(BASE))
    assert main(["--pair", f"{cur_path}:{base_path}"]) == 0
    # whole baseline file missing: pair skipped, not an error
    assert main(["--pair", f"{cur_path}:{tmp_path}/nope.json"]) == 0
    assert main(["--pair", f"{tmp_path}/nope.json:{base_path}"]) == 0


def test_differently_sized_topology_runs_never_alias(tmp_path):
    smoke = {"bench": "topology", "K": 8, "d": 512, "kappa": 3, "n_byz": 1,
             "rows": [{"topology": "complete", "us_per_round": 1e9}]}
    full = {"bench": "topology", "K": 16, "d": 20000, "kappa": 4,
            "n_byz": 3,
            "rows": [{"topology": "complete", "us_per_round": 100.0}]}
    argv = ["--pair", f"{_write(tmp_path, 's.json', smoke)}:"
            f"{_write(tmp_path, 'f.json', full)}"]
    assert main(argv) == 0                         # keys differ -> skipped


def _engine_doc(rows):
    return {"bench": "engine", "smoke": False, "rows": rows}


def test_engine_sweep_rows_gated(tmp_path):
    """The engine schema keys rows by (name, env, K, T, L, S), so a smoke
    sweep gates against the matching full-baseline point and the
    differently-sized point never aliases."""
    base = _engine_doc([
        {"name": "sweep_lanes", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "L": 6, "S": 4, "us_per_call": 1e5},
        {"name": "sweep_lanes", "env": "cartpole(horizon=100)", "K": 13,
         "T": 10, "L": 6, "S": 4, "us_per_call": 5e6},
    ])
    cur_ok = _engine_doc([
        {"name": "sweep_lanes", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "L": 6, "S": 4, "us_per_call": 1.5e5}])
    argv = ["--pair", f"{_write(tmp_path, 'c.json', cur_ok)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 0
    cur_bad = _engine_doc([
        {"name": "sweep_lanes", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "L": 6, "S": 4, "us_per_call": 2.5e5}])   # 2.5x
    argv = ["--pair", f"{_write(tmp_path, 'c2.json', cur_bad)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 1
    # same name at a different sweep size: keys differ -> skipped
    cur_other = _engine_doc([
        {"name": "sweep_lanes", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "L": 2, "S": 2, "us_per_call": 1e9}])
    argv = ["--pair", f"{_write(tmp_path, 'c3.json', cur_other)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 0


def _obs_doc(rows, key_fields=("name", "env", "K", "T")):
    return {"bench": "obs", "smoke": False,
            "key_fields": list(key_fields), "rows": rows}


def test_key_fields_fallback_gates_unknown_schema(tmp_path):
    """A doc outside the hard-coded schemas gates via its declared
    ``key_fields`` row identity (the bench_obs schema)."""
    base = _obs_doc([
        {"name": "fused_off", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "us_per_call": 1000.0},
        {"name": "fused_off", "env": "cartpole(horizon=100)", "K": 13,
         "T": 10, "us_per_call": 3e4},
    ])
    cur_ok = _obs_doc([
        {"name": "fused_off", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "us_per_call": 1500.0}])
    argv = ["--pair", f"{_write(tmp_path, 'c.json', cur_ok)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 0
    cur_bad = _obs_doc([
        {"name": "fused_off", "env": "cartpole(horizon=20)", "K": 3,
         "T": 5, "us_per_call": 2500.0}])              # 2.5x
    argv = ["--pair", f"{_write(tmp_path, 'c2.json', cur_bad)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 1
    # a differently-sized row never aliases a baseline point
    cur_other = _obs_doc([
        {"name": "fused_off", "env": "cartpole(horizon=20)", "K": 5,
         "T": 5, "us_per_call": 1e9}])
    argv = ["--pair", f"{_write(tmp_path, 'c3.json', cur_other)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 0


def test_key_fields_doc_level_fallback_and_unknown_still_skipped(tmp_path):
    """key_fields values fall back to doc-level fields (the old
    BENCH_topology layout); docs with neither a known schema nor
    key_fields never gate."""
    base = {"bench": "custom", "key_fields": ["case", "K"], "K": 8,
            "rows": [{"case": "a", "us_per_call": 1000.0}]}
    cur = {"bench": "custom", "key_fields": ["case", "K"], "K": 8,
           "rows": [{"case": "a", "us_per_call": 9000.0}]}     # 9x
    argv = ["--pair", f"{_write(tmp_path, 'c.json', cur)}:"
            f"{_write(tmp_path, 'b.json', base)}"]
    assert main(argv) == 1
    # same rows, no key_fields declaration: unknown schema, never gates
    for d in (base, cur):
        d.pop("key_fields")
    argv = ["--pair", f"{_write(tmp_path, 'c2.json', cur)}:"
            f"{_write(tmp_path, 'b2.json', base)}"]
    assert main(argv) == 0


def test_pair_argument_validation(tmp_path):
    with pytest.raises(SystemExit):
        main([])
    with pytest.raises(SystemExit):
        main(["--pair", "no-colon"])
