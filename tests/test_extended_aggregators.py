"""Centered clipping [29] + resilient momentum [23] aggregators."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import (centered_clip, get_aggregator, rfa,
                                    resilient_momentum_update)

KEY = jax.random.PRNGKey(0)


def test_centered_clip_resists_outliers():
    x = 0.1 * jax.random.normal(KEY, (13, 16))
    x = x.at[:3].set(50.0)
    hm = jnp.mean(x[3:], axis=0)
    out = centered_clip(x, tau=0.5, n_iter=20)
    assert float(jnp.linalg.norm(out - hm)) < 1.0


def test_centered_clip_no_byz_is_mean_like():
    x = 0.05 * jax.random.normal(KEY, (8, 12))
    out = centered_clip(x, tau=10.0, n_iter=5)
    np.testing.assert_allclose(out, jnp.mean(x, 0), atol=1e-4)


def test_centered_clip_factory():
    f = get_aggregator("centered_clip", K=8, n_byz=1)
    out = f(0.1 * jax.random.normal(KEY, (8, 4)), KEY)
    assert out.shape == (4,)


def test_resilient_momentum_shrinks_variance():
    """Var of aggregated momenta << var of aggregated raw gradients."""
    K, d, beta = 10, 8, 0.9
    m = jnp.zeros((K, d))
    agg = lambda x, key=None: rfa(x)
    outs_mom, outs_raw = [], []
    key = KEY
    for _ in range(50):
        key, k = jax.random.split(key)
        g = 1.0 + jax.random.normal(k, (K, d))   # true grad = 1
        m, v = resilient_momentum_update(agg, m, beta, g)
        outs_mom.append(v)
        outs_raw.append(agg(g))
    var_mom = float(jnp.var(jnp.stack(outs_mom[20:])))
    var_raw = float(jnp.var(jnp.stack(outs_raw[20:])))
    assert var_mom < 0.35 * var_raw
