"""Checkpoint round-trip tests: pytree fidelity, shape/dtype checking,
and resume-equivalence of a fused run split across a save/restore."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.core import engine
from repro.core.decbyzpg import (DecByzPGConfig, build_decbyzpg_step,
                                 init_decbyzpg_carry, run_decbyzpg)
from repro.rl.envs import make_env


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32) * 0.5},
        "step": jnp.asarray(7, jnp.int32),
        "stack": [jnp.zeros((2, 2), jnp.float16),
                  jnp.asarray([True, False])],
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    path = str(tmp_path / "state.npz")
    save(tree, path)
    template = jax.tree.map(jnp.zeros_like, tree)
    out = restore(template, path)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(tree)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert got.shape == want.shape
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


def test_restore_appends_npz_suffix(tmp_path):
    tree = {"x": jnp.ones((3,))}
    path = str(tmp_path / "ck")
    save(tree, path)                      # np.savez appends .npz itself
    out = restore(jax.tree.map(jnp.zeros_like, tree), path)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(3))


def test_restore_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save({"x": jnp.ones((3,))}, path)
    with pytest.raises(ValueError, match="shape"):
        restore({"x": jnp.zeros((4,))}, path)


def test_restore_casts_to_template_dtype(tmp_path):
    path = str(tmp_path / "ck.npz")
    save({"x": jnp.asarray([1.5, 2.5], jnp.float32)}, path)
    # dtype drift is an error by default (a silently narrowed resume is
    # not bit-identical) — casting is an explicit opt-in
    with pytest.raises(ValueError, match="cast_dtypes=True"):
        restore({"x": jnp.zeros((2,), jnp.bfloat16)}, path)
    out = restore({"x": jnp.zeros((2,), jnp.bfloat16)}, path,
                  cast_dtypes=True)
    assert out["x"].dtype == jnp.bfloat16


def test_restore_names_every_mismatched_field(tmp_path):
    """A template/file disagreement reports ALL offending fields by name
    in one error — missing, unexpected, shape, and dtype — instead of
    failing on the first leaf (the sweep-resume debugging contract)."""
    path = str(tmp_path / "ck.npz")
    save({"params": {"w": jnp.ones((3, 4)),
                     "gone": jnp.zeros((2,))},
          "step": jnp.asarray(7, jnp.int32)}, path)
    template = {"params": {"w": jnp.zeros((3, 5)),       # shape drift
                           "new": jnp.zeros((2,))},      # not in file
                "step": jnp.asarray(0, jnp.float32)}     # dtype drift
    with pytest.raises(ValueError) as ei:
        restore(template, path)
    msg = str(ei.value)
    assert "3 field(s)" in msg or "4 field(s)" in msg
    assert "params/w" in msg and "(3, 5)" in msg        # shape, by name
    assert "params/new" in msg                          # template-only
    assert "step" in msg and "float32" in msg           # dtype, by name
    assert path in msg


def test_restore_reports_file_only_fields(tmp_path):
    path = str(tmp_path / "ck.npz")
    save({"a": jnp.ones((2,)), "b": jnp.ones((2,))}, path)
    with pytest.raises(ValueError, match="b"):
        restore({"a": jnp.zeros((2,))}, path)


def test_restore_accepts_shape_dtype_struct_template(tmp_path):
    """``jax.eval_shape`` skeletons work as restore templates — the path
    sweep resume uses to validate a carry without compiling anything."""
    tree = _tree()
    path = str(tmp_path / "ck.npz")
    save(tree, path)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = restore(template, path)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


def test_resume_equivalence_across_checkpoint(tmp_path):
    """A T=6 fused run equals 3 steps + save/restore + 3 steps driven by
    the same canonical key stream (checkpointing is invisible to the
    trajectory)."""
    env = make_env("cartpole(horizon=10)")
    cfg = DecByzPGConfig(K=3, n_byz=1, attack="sign_flip",
                         aggregator="krum", N=4, B=2, kappa=2,
                         hidden=(4,), seed=3)
    T = 6
    full = run_decbyzpg(env, cfg, T)

    ks = engine.seed_keys(cfg.seed)
    step = jax.jit(build_decbyzpg_step(env, cfg))
    step_keys = jax.random.split(ks.loop, T)

    def advance(carry, lo, hi):
        rets = []
        for t in range(lo, hi):
            carry, ys = step(carry, (jnp.int32(t), step_keys[t]), ks.coin)
            rets.append(float(ys[0]))
        return carry, rets

    carry, rets_a = advance(init_decbyzpg_carry(env, cfg, ks.init), 0, 3)
    path = str(tmp_path / "mid.npz")
    save(carry, path)
    restored = restore(jax.tree.map(jnp.zeros_like, carry), path)
    for got, want in zip(jax.tree.leaves(restored),
                         jax.tree.leaves(carry)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    _, rets_b = advance(restored, 3, 6)

    np.testing.assert_allclose(np.asarray(rets_a + rets_b),
                               np.asarray(full["returns"]), atol=1e-4)


def test_resume_equivalence_telemetry_invariant(tmp_path):
    """The resumed trajectory is identical whether the step program was
    built with telemetry on or off (taps are pure observers)."""
    env = make_env("cartpole(horizon=10)")
    cfg = DecByzPGConfig(K=3, n_byz=1, attack="sign_flip",
                         aggregator="krum", N=4, B=2, kappa=2,
                         hidden=(4,), seed=1)
    ks = engine.seed_keys(cfg.seed)
    step_keys = jax.random.split(ks.loop, 4)

    def run_steps(c):
        step = jax.jit(build_decbyzpg_step(env, c))
        carry = init_decbyzpg_carry(env, c, ks.init)
        rets = []
        for t in range(4):
            carry, ys = step(carry, (jnp.int32(t), step_keys[t]), ks.coin)
            rets.append(float(ys[0]))
        return rets

    off = run_steps(cfg)
    on = run_steps(dataclasses.replace(cfg, telemetry=True))
    np.testing.assert_allclose(off, on, atol=0)
