"""Averaging agreement (paper Def. 3, Algorithm 3) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as attacks_lib
from repro.core.agreement import avg_agree, gda_mean, honest_diameter, mda_mean


def test_mda_picks_min_diameter_subset():
    x = jnp.array([[0.0], [0.1], [0.2], [10.0]])
    out = mda_mean(x, n_keep=3)
    np.testing.assert_allclose(out, [0.1], atol=1e-6)


def test_gda_mean_closest_to_own():
    x = jnp.array([[0.0], [1.0], [2.0], [50.0]])
    out = gda_mean(x, own=x[0], n_keep=3)
    np.testing.assert_allclose(out, [1.0], atol=1e-6)


@pytest.mark.parametrize("method", ["gda", "mda"])
def test_contraction_honest(method):
    """Def. 3 first property: honest diameter halves per round (>=2^k)."""
    K, d = 8, 5
    theta = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    hmask = jnp.ones((K,), bool)
    d0 = float(honest_diameter(theta, hmask))
    for kappa in (1, 3):
        out = avg_agree(theta, kappa=kappa, n_byz=0, method=method)
        dk = float(honest_diameter(out, hmask))
        assert dk <= d0 / 2 ** kappa + 1e-5, (method, kappa, dk, d0)


@pytest.mark.parametrize("method", ["gda", "mda"])
def test_contraction_under_per_receiver_attack(method):
    """Byzantines send inconsistent per-receiver garbage; honest agents
    must still contract and stay near the honest hull."""
    K, d, n_byz = 8, 4, 1
    key = jax.random.PRNGKey(1)
    theta = jax.random.normal(key, (K, d))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    attack = attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=50.0), K)
    hmask = ~byz_mask
    d0 = float(honest_diameter(theta, hmask))
    out = avg_agree(theta, kappa=4, n_byz=n_byz, byz_mask=byz_mask,
                    method=method, attack=attack, key=key)
    dk = float(honest_diameter(out, hmask))
    assert dk <= d0 / 2 + 1e-4
    # honest outputs remain within the (slightly inflated) honest range
    lo = jnp.min(theta[n_byz:], axis=0) - 0.3 * d0
    hi = jnp.max(theta[n_byz:], axis=0) + 0.3 * d0
    assert bool(jnp.all((out[n_byz:] >= lo) & (out[n_byz:] <= hi)))


def test_mean_preservation_honest_case():
    """Def. 3 second property with alpha=0: agreed mean stays close to the
    input mean."""
    K, d = 8, 6
    theta = jax.random.normal(jax.random.PRNGKey(2), (K, d))
    out = avg_agree(theta, kappa=6, n_byz=0, method="gda")
    drift = jnp.linalg.norm(jnp.mean(out, 0) - jnp.mean(theta, 0))
    diam0 = float(honest_diameter(theta, jnp.ones((K,), bool)))
    assert float(drift) <= diam0  # C_avg = O(1)


def test_avg_zero_attack_defeated_by_agreement():
    K, n_byz = 9, 2
    theta = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (K, 3)) + 5.0
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    attack = attacks_lib.get_attack("avg_zero")
    # alpha_bar must satisfy n_byz/K < alpha_bar for the guarantee to hold
    out = avg_agree(theta, kappa=4, n_byz=n_byz, byz_mask=byz_mask,
                    method="gda", attack=attack, key=jax.random.PRNGKey(4),
                    alpha_bar=0.25)
    # honest agents stay near 5.0, not dragged to 0
    assert float(jnp.min(out[n_byz:])) > 4.0
