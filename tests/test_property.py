"""Hypothesis property-based tests on system invariants (deliverable (c))."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg
from repro.distributed.aggregation import (gda_mix_matrix, stacked_mix,
                                           stacked_sq_dists)
from repro.kernels.pairwise_dist import ref as pd_ref
from repro.kernels.trimmed_mean import ref as tm_ref

SETTINGS = hypothesis.settings(max_examples=25, deadline=None)


def mats(min_k=3, max_k=12, max_d=24):
    return hnp.arrays(
        np.float32,
        st.tuples(st.integers(min_k, max_k), st.integers(1, max_d)),
        elements=st.floats(-100, 100, width=32))


@SETTINGS
@hypothesis.given(mats())
def test_pairwise_dists_metric_properties(x):
    d2 = np.asarray(pd_ref.pairwise_sq_dists(jnp.asarray(x)))
    assert np.all(d2 >= 0)
    scale = max(np.max(np.abs(x)) ** 2, 1.0)
    np.testing.assert_allclose(d2, d2.T, atol=1e-2 * scale)
    np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-2 * scale)


@SETTINGS
@hypothesis.given(mats(min_k=4), st.integers(0, 1))
def test_trimmed_mean_bounds(x, n):
    """Trimmed mean lies within [min, max] per coordinate and is
    permutation-invariant."""
    out = np.asarray(tm_ref.trimmed_mean(jnp.asarray(x), n))
    assert np.all(out >= x.min(0) - 1e-4) and np.all(out <= x.max(0) + 1e-4)
    perm = np.random.default_rng(0).permutation(x.shape[0])
    out_p = np.asarray(tm_ref.trimmed_mean(jnp.asarray(x[perm]), n))
    np.testing.assert_allclose(out, out_p, atol=1e-3)


@SETTINGS
@hypothesis.given(mats(min_k=5))
def test_rfa_translation_equivariance(x):
    shift = 7.5
    a = np.asarray(agg.rfa(jnp.asarray(x)))
    b = np.asarray(agg.rfa(jnp.asarray(x + shift)))
    scale = max(np.max(np.abs(x)), 1.0)
    np.testing.assert_allclose(b, a + shift, atol=2e-2 * scale)


@SETTINGS
@hypothesis.given(mats(min_k=5), st.integers(1, 2))
def test_krum_output_is_an_input_row(x, n_byz):
    hypothesis.assume(x.shape[0] > n_byz + 2)
    out = np.asarray(agg.krum(jnp.asarray(x), n_byz=n_byz))
    assert any(np.allclose(out, row) for row in x)


@SETTINGS
@hypothesis.given(st.integers(2, 12), st.integers(1, 12))
def test_gda_mix_matrix_row_stochastic(K, n_keep):
    n_keep = min(n_keep, K)
    x = jax.random.normal(jax.random.PRNGKey(K), (K, 4))
    d2 = pd_ref.pairwise_sq_dists(x)
    W = np.asarray(gda_mix_matrix(d2, n_keep))
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-5)
    assert np.all(W >= 0)
    # self always selected (d2[k,k]=0 is the minimum)
    assert np.all(np.diag(W) > 0)


@SETTINGS
@hypothesis.given(mats(min_k=3, max_k=8, max_d=12))
def test_stacked_dists_match_flat(x):
    """Tree-decomposed distances == flat-vector distances."""
    K, d = x.shape
    cut = d // 2
    tree = {"a": jnp.asarray(x[:, :cut]), "b": jnp.asarray(x[:, cut:])}
    got = np.asarray(stacked_sq_dists(tree))
    want = np.asarray(pd_ref.pairwise_sq_dists(jnp.asarray(x)))
    scale = max(np.max(np.abs(x)) ** 2, 1.0)
    np.testing.assert_allclose(got, want, atol=1e-3 * scale, rtol=1e-3)


# ---------------------------------------------------------------------------
# Agreement contraction (paper Def. 3) under per-receiver equivocation
# ---------------------------------------------------------------------------

AGREE_SETTINGS = hypothesis.settings(max_examples=10, deadline=None)


def _contraction(x, method, topology, kappa, n_byz=1):
    from repro.core import attacks as attacks_lib
    from repro.core.agreement import avg_agree, honest_diameter
    K = x.shape[0]
    theta = jnp.asarray(x)
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    hmask = ~byz_mask
    attack = attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=50.0), K)
    d0 = float(honest_diameter(theta, hmask))
    out = avg_agree(theta, kappa, n_byz, byz_mask, method, attack,
                    jax.random.PRNGKey(0), topology=topology)
    return d0, float(honest_diameter(out, hmask)), np.asarray(out)


@pytest.mark.slow
@AGREE_SETTINGS
@hypothesis.given(mats(min_k=6, max_k=10, max_d=6),
                  st.sampled_from(["gda", "mda"]))
def test_agreement_halves_diameter_complete_under_equivocation(x, method):
    """Def. 3 on the complete graph: κ=4 rounds at tolerated alpha shrink
    the honest diameter at least in half, even when the Byzantine agent
    equivocates per receiver, and honest outputs stay near the hull."""
    d0, dk, out = _contraction(x, method, None, kappa=4)
    hypothesis.assume(d0 > 1e-2)
    scale = max(np.max(np.abs(x)), 1.0)
    assert dk <= 0.5 * d0 + 1e-4 * scale
    lo, hi = x[1:].min(axis=0), x[1:].max(axis=0)
    assert np.all(out[1:] >= lo - 0.3 * d0) \
        and np.all(out[1:] <= hi + 0.3 * d0)


@pytest.mark.slow
@AGREE_SETTINGS
@hypothesis.given(mats(min_k=6, max_k=10, max_d=6),
                  st.sampled_from(["gda", "mda"]))
def test_agreement_contracts_sparse_ring_under_equivocation(x, method):
    """On ring(k=4) — degree 5, so one equivocating Byzantine neighbor
    stays within GDA/MDA's local tolerance — κ=8 gossip rounds still
    shrink the honest diameter (more slowly than broadcast: the rate is
    topology-dependent, which is the subsystem's point)."""
    d0, dk, _ = _contraction(x, method, "ring(k=4)", kappa=8)
    hypothesis.assume(d0 > 1e-2)
    scale = max(np.max(np.abs(x)), 1.0)
    # worst adversarial two-cluster split observed at ~0.72·d0 (GDA):
    # topology slows contraction but must still strictly shrink
    assert dk <= 0.9 * d0 + 1e-4 * scale


@SETTINGS
@hypothesis.given(mats(min_k=3, max_k=8, max_d=10))
def test_mixing_contracts_diameter(x):
    """One uniform-mix round leaves vectors in the convex hull: diameter is
    non-increasing (the Avg-Agree core invariant)."""
    K, d = x.shape
    tree = {"a": jnp.asarray(x)}
    W = jnp.full((K, K), 1.0 / K)
    out = np.asarray(stacked_mix(W, tree)["a"])
    def diam(m):
        dd = pd_ref.pairwise_sq_dists(jnp.asarray(m))
        return float(np.sqrt(np.max(np.asarray(dd))))
    assert diam(out) <= diam(x) + 1e-2 * max(np.max(np.abs(x)), 1.0)
