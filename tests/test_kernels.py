"""Per-kernel interpret-mode validation: shape/dtype sweeps vs the ref.py
pure-jnp oracles (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.pairwise_dist import ref as pd_ref
from repro.kernels.pairwise_dist.pairwise_dist import pairwise_sq_dists_pallas
from repro.kernels.trimmed_mean import ref as tm_ref
from repro.kernels.trimmed_mean.trimmed_mean import trimmed_mean_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("K,d", [(3, 17), (8, 512), (13, 1000), (16, 4096),
                                 (32, 2050)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_sweep(K, d, dtype):
    x = jax.random.normal(KEY, (K, d), dtype)
    got = pairwise_sq_dists_pallas(x, interpret=True)
    want = pd_ref.pairwise_sq_dists(x)
    tol = 1e-3 * d if dtype == jnp.bfloat16 else 1e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-2)
    # metric properties
    assert np.all(np.diag(np.asarray(got)) < tol + 1e-3)
    np.testing.assert_allclose(got, got.T, atol=tol)


@pytest.mark.parametrize("K,d,n", [(5, 33, 1), (8, 600, 2), (13, 1024, 3),
                                   (16, 100, 5)])
def test_trimmed_mean_sweep(K, d, n):
    x = jax.random.normal(KEY, (K, d))
    got = trimmed_mean_pallas(x, n, interpret=True)
    want = tm_ref.trimmed_mean(x, n)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_trimmed_mean_with_ties():
    x = jnp.ones((6, 50)).at[0].set(5.0)
    got = trimmed_mean_pallas(x, 1, interpret=True)
    want = tm_ref.trimmed_mean(x, 1)
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,hd", [
    (1, 2, 1, 64, 64, 16),
    (2, 4, 2, 96, 96, 32),
    (1, 8, 8, 128, 128, 64),
    (2, 4, 1, 100, 100, 24),        # ragged seq + GQA 4:1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, Sq, Sk, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B * H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B * Hkv, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B * Hkv, Sk, hd), dtype)
    got = flash_attention_pallas(q, k, v, n_q_heads=H, block_q=32,
                                 block_k=32, interpret=True)
    want = fa_ref.attention(q, k, v, n_q_heads=H)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [1, 7, 32, 1000])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 80, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B * H, S, hd))
    k = jax.random.normal(ks[1], (B * H, S, hd))
    v = jax.random.normal(ks[2], (B * H, S, hd))
    got = flash_attention_pallas(q, k, v, n_q_heads=H, window=window,
                                 block_q=16, block_k=16, interpret=True)
    want = fa_ref.attention(q, k, v, n_q_heads=H, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_attention_model_layout_wrapper():
    B, S, H, Hkv, hd = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    got = flash_attention(q, k, v, use_pallas=True, block_q=32, block_k=32)
    want = flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel agrees with the model's chunked-scan attention."""
    from repro.models.attention import chunked_causal_attention
    B, S, H, Hkv, hd = 1, 96, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.arange(S)
    want = chunked_causal_attention(q, k, v, pos, pos, chunk=32)
    got = flash_attention(q, k, v, use_pallas=True, block_q=32, block_k=32)
    np.testing.assert_allclose(got, want, atol=2e-5)
