"""Per-kernel interpret-mode validation: shape/dtype sweeps vs the ref.py
pure-jnp oracles (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.gossip_reduce import ref as gr_ref
from repro.kernels.gossip_reduce.gossip_reduce import (
    gossip_reduce_pallas, neighbor_reduce_pallas)
from repro.kernels.krum_score import ref as ks_ref
from repro.kernels.krum_score.krum_score import krum_scores_pallas
from repro.kernels.pairwise_dist import ref as pd_ref
from repro.kernels.pairwise_dist.pairwise_dist import pairwise_sq_dists_pallas
from repro.kernels.rfa import ref as rfa_ref
from repro.kernels.rfa.rfa import rfa_pallas
from repro.kernels.trimmed_mean import ref as tm_ref
from repro.kernels.trimmed_mean.trimmed_mean import trimmed_mean_pallas

KEY = jax.random.PRNGKey(0)


def _padded_nbr(K, deg, seed=0):
    """A (K, deg_max) neighbor table in the topology layout: sorted sender
    indices, low-degree rows padded with the receiver's own index."""
    rng = np.random.default_rng(seed + 1000 * K + deg)
    rows = []
    for r in range(K):
        d_r = rng.integers(1, deg + 1)                 # ragged real degrees
        nbrs = rng.choice(K, size=d_r, replace=False).tolist()
        rows.append(np.sort(nbrs + [r] * (deg - d_r)))
    return jnp.asarray(np.stack(rows), jnp.int32)


@pytest.mark.parametrize("K,d", [(3, 17), (8, 512), (13, 1000), (16, 4096),
                                 (32, 2050)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_sweep(K, d, dtype):
    x = jax.random.normal(KEY, (K, d), dtype)
    got = pairwise_sq_dists_pallas(x, interpret=True)
    want = pd_ref.pairwise_sq_dists(x)
    tol = 1e-3 * d if dtype == jnp.bfloat16 else 1e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-2)
    # metric properties
    assert np.all(np.diag(np.asarray(got)) < tol + 1e-3)
    np.testing.assert_allclose(got, got.T, atol=tol)


@pytest.mark.parametrize("K,d,n", [(5, 33, 1), (8, 600, 2), (13, 1024, 3),
                                   (16, 100, 5)])
def test_trimmed_mean_sweep(K, d, n):
    x = jax.random.normal(KEY, (K, d))
    got = trimmed_mean_pallas(x, n, interpret=True)
    want = tm_ref.trimmed_mean(x, n)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_trimmed_mean_with_ties():
    x = jnp.ones((6, 50)).at[0].set(5.0)
    got = trimmed_mean_pallas(x, 1, interpret=True)
    want = tm_ref.trimmed_mean(x, 1)
    np.testing.assert_allclose(got, want, atol=1e-6)


# shapes deliberately cross the d-block boundary (block_d=128 for the
# gossip kernels, 512 elsewhere), use odd K, and exercise deg_max padding
@pytest.mark.parametrize("K,P,d", [(3, 2, 17), (8, 4, 128), (9, 5, 300),
                                   (13, 13, 1000), (16, 6, 513)])
@pytest.mark.parametrize("mode,n_trim", [("mean", 0), ("median", 0),
                                         ("trimmed", 1)])
def test_gossip_reduce_sweep(K, P, d, mode, n_trim):
    if mode == "trimmed" and P <= 2 * n_trim:
        pytest.skip("trimming needs deg_max > 2*n_trim")
    msgs = jax.random.normal(KEY, (K, d))
    nbr = _padded_nbr(K, P)
    got = gossip_reduce_pallas(msgs, nbr, mode=mode, n_trim=n_trim,
                               interpret=True)
    want = gr_ref.gossip_reduce(msgs, nbr, mode=mode, n_trim=n_trim)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("K,P,d", [(3, 2, 17), (9, 4, 300), (13, 7, 1000)])
@pytest.mark.parametrize("mode,n_trim", [("mean", 0), ("median", 0),
                                         ("trimmed", 2)])
def test_neighbor_reduce_sweep(K, P, d, mode, n_trim):
    if mode == "trimmed" and P <= 2 * n_trim:
        pytest.skip("trimming needs deg_max > 2*n_trim")
    recv = jax.random.normal(KEY, (K, P, d))
    got = neighbor_reduce_pallas(recv, mode=mode, n_trim=n_trim,
                                 interpret=True)
    want = gr_ref.neighbor_reduce(recv, mode=mode, n_trim=n_trim)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


def test_gossip_reduce_median_with_ties():
    """Tie-broken ranks must reproduce the oracle exactly on constant
    columns (the degenerate case rank networks get wrong first)."""
    msgs = jnp.ones((7, 40)).at[0].set(3.0).at[5].set(-2.0)
    nbr = _padded_nbr(7, 4)
    for mode, nt in (("median", 0), ("trimmed", 1)):
        got = gossip_reduce_pallas(msgs, nbr, mode=mode, n_trim=nt,
                                   interpret=True)
        want = gr_ref.gossip_reduce(msgs, nbr, mode=mode, n_trim=nt)
        np.testing.assert_allclose(got, want, atol=0)


def test_gossip_reduce_bad_args():
    msgs = jnp.ones((4, 8))
    nbr = jnp.zeros((4, 3), jnp.int32)
    with pytest.raises(ValueError, match="mode"):
        gr_ref.gossip_reduce(msgs, nbr, mode="sum")
    with pytest.raises(ValueError, match="deg_max"):
        gossip_reduce_pallas(msgs, nbr, mode="trimmed", n_trim=2,
                             interpret=True)


@pytest.mark.parametrize("K,d", [(3, 17), (8, 512), (13, 1000), (16, 4096)])
@pytest.mark.parametrize("n_iter", [1, 16])
def test_rfa_sweep(K, d, n_iter):
    x = jax.random.normal(KEY, (K, d)) + 1.5
    got = rfa_pallas(x, n_iter=n_iter, interpret=True)
    want = rfa_ref.rfa(x, n_iter=n_iter)
    # Gram-space distances lose a few bits to cancellation vs the direct
    # subtraction — the iteration is self-correcting, so the fixed points
    # agree to ~1e-5 relative
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(got, want, atol=2e-4 * max(scale, 1.0))


def test_rfa_resists_outlier():
    """The kernel's geometric median, like the oracle's, ignores a far
    outlier (the property the aggregator relies on)."""
    x = jnp.concatenate([jnp.ones((6, 64)), jnp.full((1, 64), 1e3)])
    z = rfa_pallas(x, n_iter=64, interpret=True)
    assert float(jnp.max(jnp.abs(z - 1.0))) < 1e-2


@pytest.mark.parametrize("K,d,n_near", [(4, 33, 1), (9, 300, 4),
                                        (13, 1000, 8), (16, 513, 13)])
def test_krum_score_sweep(K, d, n_near):
    x = jax.random.normal(KEY, (K, d))
    got = krum_scores_pallas(x, n_near=n_near, interpret=True)
    want = ks_ref.krum_scores(x, n_near=n_near)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * d)


def test_krum_score_ranks_outlier_last():
    x = jnp.zeros((8, 50)).at[3].set(100.0) \
        + 0.01 * jax.random.normal(KEY, (8, 50))
    got = krum_scores_pallas(x, n_near=4, interpret=True)
    assert int(jnp.argmax(got)) == 3
    assert int(jnp.argmax(ks_ref.krum_scores(x, n_near=4))) == 3


@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,hd", [
    (1, 2, 1, 64, 64, 16),
    (2, 4, 2, 96, 96, 32),
    (1, 8, 8, 128, 128, 64),
    (2, 4, 1, 100, 100, 24),        # ragged seq + GQA 4:1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, Sq, Sk, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B * H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B * Hkv, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B * Hkv, Sk, hd), dtype)
    got = flash_attention_pallas(q, k, v, n_q_heads=H, block_q=32,
                                 block_k=32, interpret=True)
    want = fa_ref.attention(q, k, v, n_q_heads=H)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [1, 7, 32, 1000])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 80, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B * H, S, hd))
    k = jax.random.normal(ks[1], (B * H, S, hd))
    v = jax.random.normal(ks[2], (B * H, S, hd))
    got = flash_attention_pallas(q, k, v, n_q_heads=H, window=window,
                                 block_q=16, block_k=16, interpret=True)
    want = fa_ref.attention(q, k, v, n_q_heads=H, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_attention_model_layout_wrapper():
    B, S, H, Hkv, hd = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    got = flash_attention(q, k, v, use_pallas=True, block_q=32, block_k=32)
    want = flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel agrees with the model's chunked-scan attention."""
    from repro.models.attention import chunked_causal_attention
    B, S, H, Hkv, hd = 1, 96, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.arange(S)
    want = chunked_causal_attention(q, k, v, pos, pos, chunk=32)
    got = flash_attention(q, k, v, use_pallas=True, block_q=32, block_k=32)
    np.testing.assert_allclose(got, want, atol=2e-5)
