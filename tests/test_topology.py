"""Communication-topology subsystem tests (DESIGN.md §5): generators,
diagnostics, masked agreement equivalence-to-broadcast, sparse
contraction, config/engine wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as attacks_lib
from repro.core import engine
from repro.core.agreement import MDA_MAX_AGENTS, avg_agree, honest_diameter
from repro.core.decbyzpg import (DecByzPGConfig, run_decbyzpg,
                                 run_decbyzpg_legacy)
from repro.core.registry import REGISTRY
from repro.rl.envs import make_cartpole
from repro.topology import Topology, make_topology, resolve_topology


# ---------------------------------------------------------------------------
# Generators + diagnostics
# ---------------------------------------------------------------------------


def test_complete_topology_identity_gather():
    t = resolve_topology("complete", 7)
    assert t.adjacency.all()
    assert t.deg_max == 7 and t.min_in_degree == 6
    # the padded gather table is the identity permutation per receiver —
    # the property that makes the masked core reproduce the broadcast
    np.testing.assert_array_equal(t.nbr_idx,
                                  np.tile(np.arange(7), (7, 1)))
    assert t.is_complete() and t.density == 1.0
    assert t.spectral_gap == pytest.approx(1.0)


def test_ring_structure_and_padding():
    t = resolve_topology("ring(k=4)", 10)
    assert t.deg_max == 5                    # 4 neighbors + self
    assert t.min_in_degree == 4
    assert np.array_equal(t.adjacency, t.adjacency.T)
    np.testing.assert_array_equal(np.diag(t.adjacency), True)
    # receiver 0 hears {8, 9, 0, 1, 2}
    assert set(t.nbr_idx[0]) == {8, 9, 0, 1, 2}
    assert t.algebraic_connectivity > 0      # connected
    with pytest.raises(ValueError, match="even"):
        resolve_topology("ring(k=3)", 10)


def test_ring_saturates_to_complete():
    assert resolve_topology("ring(k=8)", 6).is_complete()


def test_torus_degrees():
    t = resolve_topology("torus", 9)         # 3x3
    assert (t.in_degree == 5).all()          # 4-neighborhood + self
    assert np.array_equal(t.adjacency, t.adjacency.T)
    with pytest.raises(ValueError, match="divide"):
        resolve_topology("torus(rows=4)", 9)


def test_star_structure():
    t = resolve_topology("star", 6)
    assert t.min_in_degree == 1
    assert t.deg_max == 6                    # hub hears everyone
    assert not t.tolerates(1)                # connectivity 1 < 2f+1
    assert t.adjacency[0].all() and t.adjacency[:, 0].all()


def test_erdos_renyi_deterministic_per_seed():
    a = resolve_topology("erdos_renyi(p=0.4, seed=3)", 12)
    b = resolve_topology("erdos_renyi(p=0.4, seed=3)", 12)
    c = resolve_topology("erdos_renyi(p=0.4, seed=4)", 12)
    np.testing.assert_array_equal(a.adjacency, b.adjacency)
    assert not np.array_equal(a.adjacency, c.adjacency)
    assert a is b                            # resolution cache hit
    # p=0 keeps only self-loops: disconnected, Fiedler value 0
    empty = resolve_topology("erdos_renyi(p=0)", 5)
    assert empty.min_in_degree == 0
    assert empty.algebraic_connectivity == pytest.approx(0.0)


def test_small_world_keeps_degree_even_spread():
    t = resolve_topology("small_world(k=4, beta=0.3, seed=1)", 16)
    assert np.array_equal(t.adjacency, t.adjacency.T)
    # a node always keeps its own k/2 rightward edges, and each rewire
    # moves exactly one edge endpoint, so degree >= k/2 and the total
    # edge count is preserved
    assert t.min_in_degree >= 2
    assert (t.in_degree - 1).sum() == 16 * 4


def test_make_topology_forces_self_loops_and_validates():
    adj = np.zeros((4, 4), bool)
    t = make_topology("custom", adj)
    np.testing.assert_array_equal(np.diag(t.adjacency), True)
    with pytest.raises(ValueError, match="square"):
        make_topology("bad", np.zeros((3, 4), bool))
    with pytest.raises(ValueError, match="K=5"):
        resolve_topology(t, 5)               # K mismatch


# ---------------------------------------------------------------------------
# Masked agreement core
# ---------------------------------------------------------------------------


def _per_receiver_noise(K, sigma=50.0):
    return attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=sigma), K)


def _broadcast_avg_agree_reference(theta, kappa, n_byz, byz_mask, method,
                                   attack, key):
    """The pre-topology all-to-all core, inlined verbatim as a golden
    reference: dense (K, K, d) message tensor, no gather. An independent
    pin for the equivalence-to-broadcast invariant — a regression in the
    masked core's complete-graph numerics fails here even though both
    ``topology=None`` and ``topology='complete'`` share one code path."""
    from repro.core.registry import resolve
    K, d = theta.shape
    m = resolve("agreement", method)
    n_keep = max(min(int(np.ceil((1.0 - m.alpha_bar) * K)), K - n_byz), 1)

    def one_round(th, k):
        msgs = th[None].repeat(K, axis=0)                # (recv, send, d)
        if attack is not None:
            a = attack(th, byz_mask, k)
            msgs = a if a.ndim == 3 else a[None].repeat(K, axis=0)
            msgs = jnp.where(byz_mask[None, :, None], msgs,
                             th[None].repeat(K, axis=0))
        new = jax.vmap(lambda recv, own: m.select(recv, own, n_keep)
                       )(msgs, th)
        return new, None

    out, _ = jax.lax.scan(one_round, theta, jax.random.split(key, kappa))
    return out


@pytest.mark.parametrize("method", ["gda", "mda"])
def test_complete_topology_reproduces_broadcast_exactly(method):
    """Equivalence-to-broadcast invariant (acceptance criterion): the
    masked core on the complete graph replays the historical broadcast
    implementation — same PRNG stream, equal output — for honest,
    consistent-attack, and per-receiver-equivocation rounds."""
    K, d, n_byz = 8, 5, 2
    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (K, d))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    for attack in (None, attacks_lib.get_attack("avg_zero"),
                   _per_receiver_noise(K)):
        k = key if attack is not None else None
        want = _broadcast_avg_agree_reference(
            theta, 3, n_byz, byz_mask, method, attack,
            jax.random.PRNGKey(0) if k is None else k)
        for topology in (None, "complete"):
            got = avg_agree(theta, 3, n_byz, byz_mask, method, attack, k,
                            topology=topology)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=0, rtol=0)


@pytest.mark.parametrize("spec", ["ring(k=4)", "torus",
                                  "small_world(k=4, beta=0.3, seed=0)"])
@pytest.mark.parametrize("method", ["gda", "mda"])
def test_sparse_agreement_contracts(spec, method):
    """κ gossip rounds shrink the honest diameter on sparse graphs, under
    per-receiver Byzantine equivocation."""
    K, d, n_byz = 9, 4, 1
    key = jax.random.PRNGKey(2)
    theta = jax.random.normal(key, (K, d))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    hmask = ~byz_mask
    d0 = float(honest_diameter(theta, hmask))
    out = avg_agree(theta, 6, n_byz, byz_mask, method,
                    _per_receiver_noise(K), key, topology=spec)
    dk = float(honest_diameter(out, hmask))
    assert dk < 0.5 * d0, (spec, method, dk, d0)
    # honest outputs stay within the (slightly inflated) honest hull
    lo = jnp.min(theta[n_byz:], axis=0) - 0.3 * d0
    hi = jnp.max(theta[n_byz:], axis=0) + 0.3 * d0
    assert bool(jnp.all((out[n_byz:] >= lo) & (out[n_byz:] <= hi)))


def test_mda_sparse_beyond_complete_limit():
    """MDA's subset blowup is bounded by the neighborhood, not K: a sparse
    graph keeps MDA usable where the complete graph raises."""
    K = MDA_MAX_AGENTS + 4
    theta = jax.random.normal(jax.random.PRNGKey(0), (K, 3))
    out = avg_agree(theta, 2, 0, method="mda", topology="ring(k=4)")
    assert np.isfinite(np.asarray(out)).all()
    # avg_agree pre-checks via the factory's registry metadata...
    with pytest.raises(ValueError, match="neighbor multisets up to 16"):
        avg_agree(theta, 2, 0, method="mda")
    # ...and mda_mean itself guards direct callers
    from repro.core.agreement import mda_mean
    with pytest.raises(ValueError, match="MDA_MAX_AGENTS"):
        mda_mean(theta, n_keep=K - 2)
    assert REGISTRY.meta("agreement", "mda")["max_agents"] == \
        MDA_MAX_AGENTS


def test_attack_requires_explicit_key():
    theta = jax.random.normal(jax.random.PRNGKey(0), (6, 3))
    with pytest.raises(ValueError, match="explicit PRNG"):
        avg_agree(theta, 2, 1, jnp.asarray(np.arange(6) < 1),
                  "gda", attacks_lib.get_attack("large_noise"))
    # honest rounds still work keyless
    out = avg_agree(theta, 2, 0, method="gda")
    assert np.isfinite(np.asarray(out)).all()


def test_per_edge_equivocation_differs_from_consistent_attack():
    """Per-receiver equivocation must actually deliver different values
    along different edges: outcomes differ from the consistent attack."""
    K, n_byz = 8, 2
    key = jax.random.PRNGKey(5)
    theta = jax.random.normal(key, (K, 4))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    consistent = attacks_lib.get_attack("large_noise", sigma=5.0)
    out_c = avg_agree(theta, 1, n_byz, byz_mask, "gda", consistent, key,
                      topology="ring(k=4)")
    out_e = avg_agree(theta, 1, n_byz, byz_mask, "gda",
                      attacks_lib.per_receiver(consistent, K), key,
                      topology="ring(k=4)")
    assert not np.allclose(np.asarray(out_c), np.asarray(out_e))


# ---------------------------------------------------------------------------
# Config / engine wiring
# ---------------------------------------------------------------------------

ENV = make_cartpole(horizon=20)
T = 5
BASE = dict(K=6, n_byz=1, attack="sign_flip", aggregator="rfa",
            agreement="gda", kappa=2, N=4, B=2, eta=1e-2, hidden=(8,),
            seed=3)


def test_fused_matches_legacy_on_sparse_topology():
    """The scan-vs-dispatch equivalence invariant extends to gossip
    graphs (masked gather inside the fused scan)."""
    cfg = DecByzPGConfig(per_receiver=True, topology="ring(k=4)", **BASE)
    fused = run_decbyzpg(ENV, cfg, T)
    legacy = run_decbyzpg_legacy(ENV, cfg, T)
    np.testing.assert_allclose(fused["returns"], legacy["returns"],
                               atol=1e-5)
    np.testing.assert_allclose(fused["theta"], legacy["theta"], atol=1e-6)
    np.testing.assert_allclose(fused["diameter"], legacy["diameter"],
                               atol=1e-6)


def test_default_config_is_complete_and_static_key_stable():
    """topology participates in static_key: the default and explicit
    complete configs share one compiled loop; a sparse spec gets its
    own."""
    c_default = DecByzPGConfig(**BASE)
    c_complete = DecByzPGConfig(topology="complete", **BASE)
    c_ring = DecByzPGConfig(topology="ring(k=4)", **BASE)
    assert c_default == c_complete
    assert engine.static_key(c_default) == engine.static_key(c_complete)
    assert engine.static_key(c_default) != engine.static_key(c_ring)
    r1 = run_decbyzpg(ENV, c_default, T)
    n = engine.compile_count()
    r2 = run_decbyzpg(ENV, c_complete, T)    # cache hit
    assert engine.compile_count() == n
    np.testing.assert_array_equal(np.asarray(r1["theta"]),
                                  np.asarray(r2["theta"]))


def test_topology_axis_sweep_end_to_end(tmp_path):
    """Acceptance criterion: Experiment sweeps a topology axis, reports
    Δ₂ alongside returns, and round-trips through JSON."""
    from repro.core.engine import Experiment
    specs = ("complete", "ring(k=4)")
    exp = Experiment(algo="decbyzpg", env="cartpole(horizon=20)", T=T,
                     seeds=2, axes={"topology": specs},
                     K=6, n_byz=1, attack="avg_zero", per_receiver=True,
                     aggregator="rfa", agreement="gda", kappa=2,
                     N=4, B=2, hidden=(8,))
    res = exp.run()
    assert len(res) == 2
    for spec in specs:
        out = res.sel(topology=spec)
        assert out["returns"].shape == (2, T)
        assert out["diameter"].shape == (2, T)
        assert np.isfinite(out["final_diameter_mean"])
    summ = exp.summary()
    assert all("honest_diameter_final" in v for v in summ.values())
    path = tmp_path / "topo.json"
    doc = exp.to_json(path)
    assert path.exists()
    assert {d["scenario"]["topology"] for d in doc["scenarios"]} == \
        set(specs)
    assert all("honest_diameter_final" in d for d in doc["scenarios"])


def test_grid_override_cannot_mutate_topology_axis():
    from repro.core.engine import ScenarioGrid, run_grid
    with pytest.raises(ValueError, match="topology"):
        run_grid(ENV, ScenarioGrid(seeds=(0,),
                                   axes={"topology": ("complete",
                                                      "ring(k=4)")}),
                 T, algo="decbyzpg",
                 override=lambda c: dataclasses.replace(c,
                                                        topology="star"),
                 K=6, N=4, B=2, kappa=1, hidden=(8,))
