"""Multi-process sweep smoke (DESIGN.md §12): two cooperating CPU
processes span one lane mesh via ``jax.distributed`` (gloo transport)
and must print summaries identical to each other and to a single-process
run of the same grid.  Runs out-of-process because ``jax.distributed``
must initialize before jax does anything else."""
import os
import socket
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

GRID_ARGS = ["--algo", "decbyzpg", "--env", "cartpole(horizon=10)",
             "--T", "4", "--seeds", "2", "--windows", "2",
             "--axis", "eta=5e-3,1e-2",
             "--set", "K=3", "--set", "n_byz=1",
             "--set", "attack=large_noise(sigma=10)",
             "--set", "N=4", "--set", "B=2", "--set", "kappa=1",
             "--set", "hidden=(4,)"]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _launch(extra, wait=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.sweep"] + GRID_ARGS + extra,
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True)
    if not wait:
        return proc
    out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err[-3000:]
    return out


def _summary_lines(out: str) -> list:
    return sorted(ln for ln in out.splitlines() if "final_return" in ln)


def test_two_process_span_matches_single_process():
    ref = _summary_lines(_launch([]))
    assert len(ref) == 2

    port = _free_port()
    flags = ["--mode", "span", "--processes", "2",
             "--coordinator", f"localhost:{port}"]
    p0 = _launch(flags + ["--process-id", "0"], wait=False)
    p1 = _launch(flags + ["--process-id", "1"], wait=False)
    out0, err0 = p0.communicate(timeout=600)
    out1, err1 = p1.communicate(timeout=600)
    assert p0.returncode == 0, err0[-3000:]
    assert p1.returncode == 0, err1[-3000:]
    # every process computes (and can report) the full merged result, and
    # the spanning-mesh run reproduces the single-process numbers
    assert _summary_lines(out0) == _summary_lines(out1) == ref
