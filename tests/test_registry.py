"""Component-spec registry tests (DESIGN.md §4): Spec parsing/formatting
round-trips, nested specs, error reporting, resolution context plumbing,
and string-config backward compatibility."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.registry import REGISTRY, Spec, SpecError, resolve


# ---------------------------------------------------------------------------
# Spec parsing / canonical round-trips
# ---------------------------------------------------------------------------

def test_bare_name_round_trip():
    s = Spec.parse("krum")
    assert s.name == "krum" and s.kwargs == ()
    assert s.canonical() == "krum"
    assert Spec.parse(s.canonical()) == s


def test_kwargs_round_trip_and_ordering():
    a = Spec.parse("krum(m=3)")
    assert a.canonical() == "krum(m=3)"
    # kwargs are stored key-sorted, so argument order doesn't matter
    x = Spec.parse("rfa(nu=1e-6, n_iter=64)")
    y = Spec.parse("rfa(n_iter=64, nu=1e-6)")
    assert x == y and hash(x) == hash(y)
    assert x.canonical() == y.canonical()
    assert Spec.parse(x.canonical()) == x


def test_nested_spec_round_trip():
    s = Spec.parse("bucketing(s=2, inner=rfa(n_iter=64))")
    assert s.canonical() == "bucketing(inner=rfa(n_iter=64), s=2)"
    assert Spec.parse(s.canonical()) == s
    inner = dict(s.kwargs)["inner"]
    assert isinstance(inner, Spec) and inner.name == "rfa"
    assert dict(inner.kwargs) == {"n_iter": 64}


def test_value_types_round_trip():
    s = Spec("demo", f=1.5, neg=-2, flag=True, none=None, s="x'y",
             tup=(1, 2))
    assert Spec.parse(s.canonical()) == s


def test_spec_equivalence_constructor_vs_parse():
    assert Spec.parse("large_noise(sigma=10)") == Spec("large_noise",
                                                       sigma=10)
    s = Spec("rfa", n_iter=8)
    assert Spec.of(s) is s                               # idempotent


def test_spec_is_immutable_and_hashable():
    s = Spec("krum", m=3)
    with pytest.raises(AttributeError):
        s.name = "other"
    assert len({s, Spec("krum", m=3), Spec("krum")}) == 2


@pytest.mark.parametrize("bad", [
    "krum(3)",              # positional args
    "krum(m=3",             # unbalanced parens
    "kr um",                # not an identifier
    "krum(m=[)]",           # garbage
    "f(**kw)",              # ** not allowed
])
def test_bad_spec_strings_raise(bad):
    with pytest.raises(SpecError):
        Spec.parse(bad)


def test_non_finite_kwargs_rejected():
    # inf/nan would not round-trip through the canonical string
    with pytest.raises(SpecError):
        Spec("f", x=float("inf"))
    with pytest.raises(SpecError):
        Spec("f", x=float("nan"))


def test_spec_pickle_round_trip():
    import pickle
    s = Spec.parse("bucketing(inner=rfa(n_iter=64), s=2)")
    assert pickle.loads(pickle.dumps(s)) == s


# ---------------------------------------------------------------------------
# Resolution: context plumbing, parameterized + nested components, errors
# ---------------------------------------------------------------------------

def test_unknown_component_lists_registered_names():
    with pytest.raises(KeyError, match="rfa"):
        resolve("aggregator", "definitely_not_registered")


def test_bad_kwarg_raises_before_factory_runs():
    with pytest.raises(TypeError, match="bogus"):
        resolve("aggregator", "krum(bogus=1)", K=8, n_byz=2)


def test_parameterized_and_nested_aggregators_resolve():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    key = jax.random.PRNGKey(1)
    for spec in ("mean", "krum", "krum(m=3)", "rfa(n_iter=8)",
                 "bucketing(inner=rfa(n_iter=64), s=2)",
                 "bucketing(inner=krum(m=2), s=2)"):
        out = resolve("aggregator", spec, K=8, n_byz=2)(x, key)
        assert out.shape == (5,)
        assert np.all(np.isfinite(np.asarray(out)))


def test_spec_kwargs_override_context():
    # trimmed_mean's n_byz comes from context, but an explicit spec kwarg
    # wins over it
    x = jnp.asarray(np.arange(24, dtype=np.float32).reshape(8, 3))
    explicit = resolve("aggregator", "trimmed_mean(n_byz=3)",
                       K=8, n_byz=1)(x)
    # trimming 3 from each end of 8 sorted rows leaves rows 3..4
    np.testing.assert_allclose(np.asarray(explicit),
                               np.asarray(x[3:5].mean(axis=0)), atol=1e-6)


def test_env_namespace_resolves_with_kwargs():
    from repro.rl.envs import make_env
    env = make_env("cartpole(horizon=37)")
    assert env.name == "cartpole" and env.horizon == 37
    assert make_env("lunarlander").n_actions == 4


def test_attack_env_level_metadata():
    from repro.core import attacks
    assert attacks.is_env_level("random_action")
    assert not attacks.is_env_level("large_noise(sigma=10)")
    assert not attacks.is_env_level(Spec("avg_zero"))


def test_optimizer_and_estimator_namespaces():
    from repro.optim.optimizers import get_optimizer
    opt = get_optimizer("sgd(momentum=0.5)", 1e-2)
    p = jnp.ones((3,))
    s = opt.init(p)
    p2, _ = opt.update(jnp.ones((3,)), s, p)
    np.testing.assert_allclose(np.asarray(p2), 1.01, atol=1e-6)
    assert resolve("estimator", "gpomdp") is not None
    assert resolve("agreement", "gda").alpha_bar == 0.2
    assert resolve("agreement", "gda(alpha_bar=0.25)").alpha_bar == 0.25


def test_registry_names_nonempty_per_namespace():
    for ns in ("aggregator", "attack", "agreement", "estimator",
               "optimizer", "env", "algo", "fed_aggregator", "fed_attack"):
        assert REGISTRY.names(ns), ns


# ---------------------------------------------------------------------------
# String-config backward compatibility
# ---------------------------------------------------------------------------

def test_config_string_and_spec_forms_hash_equal():
    from repro.core.byzpg import ByzPGConfig
    from repro.core.decbyzpg import DecByzPGConfig
    a = DecByzPGConfig(aggregator="rfa", attack="large_noise(sigma=10)")
    b = DecByzPGConfig(aggregator=Spec("rfa"),
                       attack=Spec("large_noise", sigma=10))
    assert a == b and hash(a) == hash(b)
    assert engine.static_key(a) == engine.static_key(b)
    assert isinstance(a.aggregator, Spec)
    c = ByzPGConfig(aggregator="krum(m=2)")
    d = ByzPGConfig(aggregator=Spec("krum", m=2))
    assert c == d and hash(c) == hash(d)


def test_config_replace_keeps_specs():
    from repro.core.decbyzpg import DecByzPGConfig
    cfg = dataclasses.replace(DecByzPGConfig(aggregator="rfa"), seed=3)
    assert isinstance(cfg.aggregator, Spec) and cfg.aggregator.name == "rfa"


def test_fed_config_normalizes_to_specs():
    from repro.distributed.fed_trainer import FedConfig
    fed = FedConfig(aggregator="rfa(n_iter=16)",
                    attack="large_noise(sigma=5)", optimizer="sgd")
    assert fed.aggregator == Spec("rfa", n_iter=16)
    assert fed.attack.canonical() == "large_noise(sigma=5)"
    assert hash(fed) == hash(FedConfig(
        aggregator=Spec("rfa", n_iter=16),
        attack=Spec("large_noise", sigma=5), optimizer=Spec("sgd")))


def test_run_decbyzpg_accepts_parameterized_specs():
    """A parameterized spec string resolves through the registry into the
    fused scan loop, and the compiled-loop cache hits on the repeat."""
    from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
    from repro.rl.envs import make_env
    env = make_env("cartpole(horizon=16)")
    cfg = DecByzPGConfig(K=3, n_byz=1, attack="large_noise(sigma=10)",
                         aggregator="bucketing(inner=rfa(n_iter=16), s=2)",
                         agreement="gda(alpha_bar=0.25)", kappa=1,
                         N=4, B=2, hidden=(8,), seed=0)
    out = run_decbyzpg(env, cfg, 3)
    n = engine.compile_count()
    again = run_decbyzpg(env, cfg, 3)
    assert engine.compile_count() == n
    np.testing.assert_array_equal(out["returns"], again["returns"])
