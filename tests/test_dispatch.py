"""Kernel-backend dispatch layer tests (DESIGN.md §6): backend selection
precedence, the ``kernel`` registry namespace, and jnp-vs-interpret
agreement inside a jitted ``avg_agree`` round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as attacks_lib
from repro.core.agreement import avg_agree
from repro.core.registry import REGISTRY, resolve
from repro.kernels import dispatch

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_backend():
    yield
    dispatch.set_backend(None)


def test_kernel_namespace_lists_suite():
    names = REGISTRY.names("kernel")
    for expected in ("pairwise_dist", "trimmed_mean", "gossip_reduce",
                     "neighbor_reduce", "rfa", "krum_score",
                     "flash_attention"):
        assert expected in names


def test_registry_resolve_returns_dispatching_kernel():
    k = resolve("kernel", "trimmed_mean")
    assert k is dispatch.get_kernel("trimmed_mean")
    x = jax.random.normal(KEY, (8, 64))
    np.testing.assert_allclose(k(x, 1, backend="jnp"),
                               k(x, 1, backend="pallas-interpret"),
                               atol=1e-6)


def test_unknown_kernel_and_backend_raise():
    with pytest.raises(KeyError, match="unknown kernel"):
        dispatch.get_kernel("nope")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.get_kernel("trimmed_mean")(jnp.ones((4, 8)), 1,
                                            backend="cuda")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.set_backend("tpu")


def test_backend_precedence(monkeypatch):
    # auto: jnp off-TPU
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    if not dispatch.on_tpu():
        assert dispatch.current_backend() == "jnp"
    # env var overrides auto
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas-interpret")
    assert dispatch.current_backend() == "pallas-interpret"
    # global override beats env var
    dispatch.set_backend("jnp")
    assert dispatch.current_backend() == "jnp"
    # scoped override restores the previous global
    with dispatch.use_backend("pallas-interpret"):
        assert dispatch.current_backend() == "pallas-interpret"
    assert dispatch.current_backend() == "jnp"


def test_env_var_validated(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "fast-please")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.default_backend()


@pytest.mark.parametrize("method", ["cwmean", "cwmed", "cwtm"])
def test_avg_agree_backends_agree_honest(method):
    """backend="jnp" vs backend="pallas-interpret" inside a jitted
    avg_agree round (fused gather + reduce path, ring topology)."""
    theta = jax.random.normal(KEY, (9, 130))         # crosses one d-block
    outs = {}
    for backend in ("jnp", "pallas-interpret"):
        fn = jax.jit(lambda th, b=backend: avg_agree(
            th, kappa=2, n_byz=1, method=method, topology="ring(k=4)",
            kernel_backend=b))
        outs[backend] = fn(theta)
    np.testing.assert_allclose(outs["jnp"], outs["pallas-interpret"],
                               atol=1e-5, rtol=1e-5)


def test_avg_agree_backends_agree_under_equivocation():
    """Per-receiver equivocation exercises the neighbor_reduce path; both
    backends must agree inside the same jitted round on the same keys."""
    K, n_byz = 8, 1
    theta = jax.random.normal(KEY, (K, 70))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    attack = attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=10.0), K)
    outs = {}
    for backend in ("jnp", "pallas-interpret"):
        fn = jax.jit(lambda th, k, b=backend: avg_agree(
            th, kappa=3, n_byz=n_byz, byz_mask=byz_mask, method="cwtm",
            attack=attack, key=k, topology="ring(k=4)", kernel_backend=b))
        outs[backend] = fn(theta, jax.random.PRNGKey(7))
    np.testing.assert_allclose(outs["jnp"], outs["pallas-interpret"],
                               atol=1e-5, rtol=1e-5)


def test_avg_agree_cwtm_contracts_under_attack():
    """The kernel-routed coordinate-wise methods are real agreement rules:
    trimmed gossip shrinks the honest diameter under a consistent attack."""
    from repro.core.agreement import honest_diameter
    K, n_byz = 10, 1
    theta = jax.random.normal(KEY, (K, 16))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    hmask = ~byz_mask
    attack = attacks_lib.get_attack("large_noise", sigma=50.0)
    d0 = float(honest_diameter(theta, hmask))
    out = avg_agree(theta, kappa=4, n_byz=n_byz, byz_mask=byz_mask,
                    method="cwtm", attack=attack, key=jax.random.PRNGKey(3))
    assert float(honest_diameter(out, hmask)) < d0 / 2


def test_global_backend_reroutes_aggregator():
    """aggregators.* route through the dispatcher: flipping the global
    backend changes the executed path but not the value."""
    from repro.core.aggregators import rfa, trimmed_mean
    x = jax.random.normal(KEY, (8, 200))
    with dispatch.use_backend("jnp"):
        tm_j, rfa_j = trimmed_mean(x, 1), rfa(x, n_iter=8)
    with dispatch.use_backend("pallas-interpret"):
        tm_p, rfa_p = trimmed_mean(x, 1), rfa(x, n_iter=8)
    np.testing.assert_allclose(tm_j, tm_p, atol=1e-6)
    np.testing.assert_allclose(rfa_j, rfa_p, atol=1e-4)


def test_auto_size_threshold_falls_back_to_jnp(monkeypatch):
    """Auto mode on TPU dispatches tiny stacks to the oracle: below a
    kernel's ``auto_jnp_below`` first-operand element count the Pallas
    launch overhead dominates, so auto picks jnp; at/above the cutoff it
    stays on pallas. Every explicit choice bypasses the fallback."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.setattr(dispatch, "on_tpu", lambda: True)
    k = dispatch.get_kernel("gossip_reduce")
    assert k.auto_jnp_below == 8192
    small = jnp.ones((8, 512))            # 4096 < 8192
    big = jnp.ones((8, 2048))             # 16384 >= 8192
    assert k.resolve_backend(small) == "jnp"
    assert k.resolve_backend(big) == "pallas"
    # per-call override wins over the size fallback
    assert k.resolve_backend(small, backend="pallas") == "pallas"
    # global override wins
    with dispatch.use_backend("pallas-interpret"):
        assert k.resolve_backend(small) == "pallas-interpret"
    # env var wins
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    assert k.resolve_backend(small) == "pallas"
    # cutoff is per-kernel metadata, visible through the registry
    assert REGISTRY.meta("kernel", "gossip_reduce")["auto_jnp_below"] == 8192
    assert REGISTRY.meta("kernel", "neighbor_reduce")["auto_jnp_below"] \
        == 32768


def test_auto_threshold_inert_off_tpu(monkeypatch):
    """Off-TPU auto already resolves to jnp; the size fallback never
    flips anything."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.setattr(dispatch, "on_tpu", lambda: False)
    k = dispatch.get_kernel("gossip_reduce")
    assert k.resolve_backend(jnp.ones((8, 512))) == "jnp"
    assert k.resolve_backend(jnp.ones((64, 4096))) == "jnp"


def test_block_d_stripped_for_jnp_oracle():
    """``block_d`` is a Pallas tiling knob: the oracle path drops it, so
    one call site can pass it unconditionally across backends."""
    from repro.kernels.rfa import ref as rfa_ref
    x = jax.random.normal(KEY, (6, 130))
    k = dispatch.get_kernel("rfa")
    out_j = k(x, n_iter=4, block_d=64, backend="jnp")
    np.testing.assert_array_equal(out_j, rfa_ref.rfa(x, n_iter=4))
    out_p = k(x, n_iter=4, block_d=64, backend="pallas-interpret")
    np.testing.assert_allclose(out_j, out_p, atol=1e-5, rtol=1e-5)
