"""Repository hygiene invariants enforced as tests.

Smoke-sized benchmark outputs (``benchmarks/*_smoke.json``) are CI/dev
artifacts regenerated per run; only the full-ladder ``BENCH_*.json``
baselines are the committed perf trajectory (DESIGN.md §6). A tracked
smoke file would silently stand in for a regression baseline, so the
"never tracked" rule is pinned here (and mirrored as a CI step) instead
of living only in reviewers' heads.
"""
import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git(*args) -> str:
    try:
        out = subprocess.run(["git", *args], cwd=REPO, capture_output=True,
                             text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip(f"not a git checkout: {out.stderr.strip()[:120]}")
    return out.stdout


def test_no_smoke_benchmark_file_is_tracked():
    tracked = [line for line in _git(
        "ls-files", "benchmarks/*_smoke.json").splitlines() if line]
    assert not tracked, (
        f"smoke benchmark outputs must stay untracked (they are per-run "
        f"artifacts, not committed baselines): {tracked}; "
        f"fix with `git rm --cached {' '.join(tracked)}`")


def test_gitignore_covers_smoke_outputs():
    """Every smoke writer targets benchmarks/*_smoke.json; the ignore
    pattern must cover the whole family so a new bench script cannot
    reintroduce a trackable smoke file."""
    with open(os.path.join(REPO, ".gitignore")) as f:
        patterns = [line.strip() for line in f
                    if line.strip() and not line.startswith("#")]
    assert "benchmarks/*_smoke.json" in patterns
