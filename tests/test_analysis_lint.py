"""lint pass: every rule fires on a seeded tmp-tree violation with
file/line context, escape hatches suppress it, doc fences are checked,
the tracked-smoke rule sees git, and the real repo plus the CLI wiring
are clean."""

import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.lint import (LintConfig, check_tracked_smoke, run)


def _write(root: Path, rel: str, body: str) -> None:
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(body))


def _lint(root: Path):
    return run(config=LintConfig(root=root))


def _one(findings, rule):
    hits = [f for f in findings if f.rule == rule]
    assert hits, f"no {rule} finding in {[f.format() for f in findings]}"
    return hits[0]


# -- literal-prng-key -------------------------------------------------------


def test_literal_prng_key_flagged(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
        import jax

        def f():
            return jax.random.PRNGKey(0)
        """)
    f = _one(_lint(tmp_path), "literal-prng-key")
    assert f.path == "src/repro/foo.py" and f.line == 4


def test_shape_only_hatch_suppresses(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
        import jax

        def f():
            # analysis: shape-only
            return jax.random.PRNGKey(0)
        """)
    assert _lint(tmp_path) == []


def test_tests_are_exempt(tmp_path):
    _write(tmp_path, "tests/test_foo.py", """\
        import jax
        KEY = jax.random.PRNGKey(0)
        """)
    assert _lint(tmp_path) == []


# -- spec-strings -----------------------------------------------------------


def test_unparseable_spec_flagged(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
        from repro.core.registry import resolve

        def f():
            return resolve("aggregator", "rfa(((")
        """)
    f = _one(_lint(tmp_path), "spec-strings")
    assert f.path == "src/repro/foo.py" and f.line == 4
    assert "rfa(((" in f.message


def test_unregistered_spec_flagged(tmp_path):
    _write(tmp_path, "src/repro/foo.py",
           'CFG = dict(aggregator="definitely_not_registered")\n')
    assert _one(_lint(tmp_path), "spec-strings").line == 1


def test_bad_kwarg_spec_flagged(tmp_path):
    _write(tmp_path, "examples/demo.py",
           'CFG = dict(attack="large_noise(bogus_kwarg=1)")\n')
    f = _one(_lint(tmp_path), "spec-strings")
    assert "bogus_kwarg" in f.message


def test_valid_spec_clean(tmp_path):
    _write(tmp_path, "src/repro/foo.py",
           'CFG = dict(attack="large_noise(sigma=10)", aggregator="rfa")\n')
    assert _lint(tmp_path) == []


def test_not_a_spec_hatch_suppresses(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
        # analysis: not-a-spec
        LABELS = dict(attack="our strongest attack (sec 5)")
        """)
    assert _lint(tmp_path) == []


def test_doc_fence_spec_rot_flagged(tmp_path):
    _write(tmp_path, "README.md", """\
        # Demo

        ```python
        from repro.core.registry import resolve
        agg = resolve("aggregator", "renamed_away")
        ```
        """)
    f = _one(_lint(tmp_path), "spec-strings")
    # line is offset into README.md, not into the fence
    assert f.path == "README.md" and f.line == 5


# -- pallas-location --------------------------------------------------------


def test_pallas_outside_kernels_flagged(tmp_path):
    _write(tmp_path, "src/repro/core/foo.py", """\
        from jax.experimental import pallas as pl

        def f(x):
            return pl.pallas_call(lambda r: r, out_shape=x)(x)
        """)
    assert _one(_lint(tmp_path), "pallas-location").line == 4


def test_pallas_inside_kernels_clean(tmp_path):
    _write(tmp_path, "src/repro/kernels/foo.py", """\
        from jax.experimental import pallas as pl

        def f(x):
            return pl.pallas_call(lambda r: r, out_shape=x)(x)
        """)
    assert _lint(tmp_path) == []


# -- numpy-traced -----------------------------------------------------------


def test_numpy_in_traced_scope_flagged(tmp_path):
    _write(tmp_path, "src/repro/core/foo.py", """\
        import numpy as np

        def build(cfg):
            def step(carry, x):
                return np.sum(carry), None
            return step
        """)
    f = _one(_lint(tmp_path), "numpy-traced")
    assert f.line == 5 and "np.sum" in f.message


def test_host_side_hatch_suppresses(tmp_path):
    _write(tmp_path, "src/repro/core/foo.py", """\
        import numpy as np

        def build(cfg):
            def step(carry, x):
                # analysis: host-side
                return np.sum(carry), None
            return step
        """)
    assert _lint(tmp_path) == []


def test_module_level_numpy_clean(tmp_path):
    _write(tmp_path, "src/repro/core/foo.py", """\
        import numpy as np
        TABLE = np.arange(8)
        """)
    assert _lint(tmp_path) == []


# -- tracked-smoke-file -----------------------------------------------------


def test_tracked_smoke_file_flagged(tmp_path):
    def git(*argv):
        subprocess.run(["git", *argv], cwd=tmp_path, check=True,
                       capture_output=True)

    git("init", "-q")
    _write(tmp_path, "benchmarks/bench_smoke.json", "{}\n")
    git("add", "benchmarks/bench_smoke.json")
    findings = check_tracked_smoke(LintConfig(root=tmp_path))
    assert [f.rule for f in findings] == ["tracked-smoke-file"]
    assert findings[0].path == "benchmarks/bench_smoke.json"


def test_untracked_smoke_file_clean(tmp_path):
    subprocess.run(["git", "init", "-q"], cwd=tmp_path, check=True,
                   capture_output=True)
    _write(tmp_path, "benchmarks/bench_smoke.json", "{}\n")
    assert check_tracked_smoke(LintConfig(root=tmp_path)) == []


# -- the real repo + CLI wiring ---------------------------------------------


def test_repo_is_clean():
    assert run() == []


def test_cli_exit_codes(monkeypatch, capsys):
    from repro.analysis import __main__ as cli

    monkeypatch.setitem(
        cli.PASSES, "lint",
        lambda: [Finding("lint", "fixture", "src/x.py", 3, "seeded")])
    assert cli.main(["--passes", "lint"]) == 1
    out = capsys.readouterr().out
    assert "src/x.py:3: [lint/fixture] seeded" in out

    monkeypatch.setitem(cli.PASSES, "lint", lambda: [])
    assert cli.main(["--passes", "lint"]) == 0


def test_cli_rejects_unknown_pass():
    import pytest

    from repro.analysis import __main__ as cli
    with pytest.raises(SystemExit):
        cli.main(["--passes", "nope"])
