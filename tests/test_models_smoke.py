"""Per-architecture smoke tests (deliverable (f)): reduced same-family
variant, one forward + one train step on CPU, asserting shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.distributed.fed_trainer import (FedConfig, fed_train_step,
                                           init_fed_state)
from repro.models.model import forward, init_params, lm_loss

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pe = None
    if cfg.frontend != "none":
        pe = jax.random.normal(KEY, (B, cfg.n_prefix_embeds, cfg.d_model))
    return toks, pe


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    params = init_params(cfg, KEY)
    toks, pe = _inputs(cfg)
    logits, aux, _ = forward(cfg, params, toks, pe)
    S_total = toks.shape[1] + (0 if pe is None else pe.shape[1])
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_no_nans(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY)
    toks, pe = _inputs(cfg)
    loss0 = lm_loss(cfg, params, toks, pe)
    g = jax.grad(lambda p: lm_loss(cfg, p, toks, pe))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(gnorm))
    assert float(gnorm) > 0
    # one SGD step decreases loss on the same batch
    params2 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    loss1 = lm_loss(cfg, params2, toks, pe)
    assert float(loss1) < float(loss0)


def test_full_configs_match_assignment():
    spec = {
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, H, kv, ff, V), arch
    assert get_config("hymba_1_5b").ssm.state_dim == 16
    assert get_config("grok_1_314b").moe.n_experts == 8
    assert get_config("grok_1_314b").moe.top_k == 2
    ds = get_config("deepseek_v2_lite_16b")
    assert ds.moe.n_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.n_shared_experts == 2 and ds.mla.kv_lora_rank == 512


def test_moe_aux_loss_and_balance():
    cfg = reduced(get_config("deepseek_v2_lite_16b"))
    params = init_params(cfg, KEY)
    toks, _ = _inputs(cfg, B=4, S=32)
    _, aux, _ = forward(cfg, params, toks)
    assert 0.0 < float(aux) < 1.0      # ~ n_layers * weight at balance


def test_fed_step_all_families_one_step():
    for arch in ["llama3_2_1b", "xlstm_350m", "hymba_1_5b"]:
        cfg = reduced(get_config(arch))
        fed = FedConfig(aggregator="trimmed_mean", kappa=1, n_byz=1,
                        attack="sign_flip", lr=1e-3)
        state = init_fed_state(cfg, fed, 4, KEY)
        batch = {"tokens": jax.random.randint(KEY, (4, 1, 16), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (4, 1, 16), 0,
                                              cfg.vocab_size)}
        mask = jnp.array([True, False, False, False])
        state, m = fed_train_step(cfg, fed, state, batch, mask, KEY,
                                  large=True)
        assert bool(jnp.isfinite(m["loss"])), arch
