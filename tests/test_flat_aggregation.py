"""Sharded flat-(K, D) aggregation layer (DESIGN.md §3): flat-vs-dense
parity, ``block_d``-tiled kernel parity at block boundaries, sharded
routing through the registry aggregators, and the 4-fake-device
subprocess checks (real NamedSharding, per-device memory
O(K² + K·D/devices), flat-vs-tree federated step parity)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import krum, rfa, trimmed_mean
from repro.core.agreement import avg_agree
from repro.core.registry import resolve
from repro.distributed import aggregation as agg_lib
from repro.kernels.gossip_reduce import ref as gr_ref
from repro.kernels.gossip_reduce.gossip_reduce import gossip_reduce_pallas
from repro.kernels.krum_score import ref as ks_ref
from repro.kernels.krum_score.krum_score import krum_scores_pallas
from repro.kernels.pairwise_dist import ref as pd_ref
from repro.kernels.pairwise_dist.pairwise_dist import pairwise_sq_dists_pallas
from repro.kernels.rfa import ref as rfa_ref
from repro.kernels.rfa.rfa import rfa_pallas

KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Flat layer vs dense aggregators (single device: same math, two routes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,D", [(5, 37), (8, 512), (13, 1000)])
def test_flat_sq_dists_matches_kernel(K, D):
    x = jax.random.normal(KEY, (K, D))
    np.testing.assert_allclose(agg_lib.flat_sq_dists(x),
                               pd_ref.pairwise_sq_dists(x),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m", [1, 3])
def test_flat_krum_matches_dense(m):
    x = jax.random.normal(KEY, (8, 600))
    got = agg_lib.flat_krum(x, n_byz=2, m=m)
    want = krum(x, n_byz=2, m=m, sharded=False)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_flat_rfa_matches_dense():
    x = jax.random.normal(KEY, (8, 600))
    got = agg_lib.flat_rfa(x, n_iter=16)
    want = rfa(x, n_iter=16, sharded=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flat_trimmed_mean_matches_dense():
    x = jax.random.normal(KEY, (9, 333))
    np.testing.assert_array_equal(agg_lib.flat_trimmed_mean(x, 2),
                                  trimmed_mean(x, 2, sharded=False))


@pytest.mark.parametrize("block", [2, 4])
def test_flat_gram_blocked_matches(block):
    x = jax.random.normal(KEY, (8, 777))
    np.testing.assert_allclose(agg_lib.flat_sq_dists(x, block=block),
                               agg_lib.flat_sq_dists(x), rtol=1e-5,
                               atol=1e-4)


def test_sharded_kwarg_routes_factories_under_jit():
    """``sharded=True`` in the spec (or resolve context) engages the flat
    path from inside jit, where eager sharding detection is unavailable —
    and agrees with the dense route."""
    x = jax.random.normal(KEY, (8, 512))
    k = jax.random.PRNGKey(1)
    for spec in ("krum(sharded=True)", "rfa(sharded=True)",
                 "trimmed_mean(sharded=True)"):
        agg_s = resolve("aggregator", spec, K=8, n_byz=1)
        agg_d = resolve("aggregator", spec.split("(")[0], K=8, n_byz=1)
        got = jax.jit(lambda a, kk: agg_s(a, kk))(x, k)
        np.testing.assert_allclose(got, agg_d(x, k), rtol=1e-4, atol=1e-4)


def test_avg_agree_sharded_flag_forces_jnp():
    """cw agreement rounds on a (claimed-)sharded stack run the jnp
    oracles — bit-identical to an explicit kernel_backend="jnp"."""
    theta = jax.random.normal(KEY, (6, 64))
    got = avg_agree(theta, kappa=2, n_byz=1, method="cwtm", sharded=True)
    want = avg_agree(theta, kappa=2, n_byz=1, method="cwtm",
                     kernel_backend="jnp")
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# block_d-tiled kernel parity: block-boundary and non-divisible D
# ---------------------------------------------------------------------------
# D values straddle the tile: one block exactly, a multiple, one short of
# the boundary, one past it, and a prime-ish tail.

BLOCK_DS = ((64, 64), (64, 128), (64, 63), (64, 65), (64, 257))


@pytest.mark.parametrize("block_d,D", BLOCK_DS)
def test_pairwise_dist_block_boundaries(block_d, D):
    x = jax.random.normal(KEY, (7, D))
    got = pairwise_sq_dists_pallas(x, block_d=block_d, interpret=True)
    np.testing.assert_allclose(got, pd_ref.pairwise_sq_dists(x),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("block_d,D", BLOCK_DS)
def test_krum_score_block_boundaries(block_d, D):
    x = jax.random.normal(KEY, (7, D))
    got = krum_scores_pallas(x, n_near=3, block_d=block_d, interpret=True)
    np.testing.assert_allclose(got, ks_ref.krum_scores(x, 3),
                               rtol=1e-4, atol=1e-4 * D)


@pytest.mark.parametrize("block_d,D", BLOCK_DS)
def test_rfa_block_boundaries(block_d, D):
    x = jax.random.normal(KEY, (7, D))
    got = rfa_pallas(x, n_iter=8, block_d=block_d, interpret=True)
    want = rfa_ref.rfa(x, n_iter=8)
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    np.testing.assert_allclose(got, want, atol=2e-4 * scale)


@pytest.mark.parametrize("block_d,D", BLOCK_DS)
def test_gossip_reduce_block_boundaries(block_d, D):
    x = jax.random.normal(KEY, (7, D))
    nbr = jnp.asarray(np.stack([np.sort((np.arange(3) + r) % 7)
                                for r in range(7)]), jnp.int32)
    got = gossip_reduce_pallas(x, nbr, mode="trimmed", n_trim=1,
                               block_d=block_d, interpret=True)
    want = gr_ref.gossip_reduce(x, nbr, mode="trimmed", n_trim=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Real NamedSharding over fake devices (subprocess: XLA flag pre-init)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_aggregation_four_fake_devices():
    """On a forced 4-device mesh with D sharded: the flat path (a) is
    detected eagerly, (b) matches the dense single-device result, and
    (c) compiles to O(K² + K·D/devices) per-device footprint at the
    reduced-transformer D — arguments shard 4-way and temporaries stay
    within a small factor of one agent-shard, where the dense route
    would gather the full stack."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.registry import resolve
from repro.distributed.aggregation import dim_sharded

mesh = Mesh(np.asarray(jax.devices()), ("model",))
sh = NamedSharding(mesh, P(None, "model"))
K, DEV = 8, 4

# (a) eager detection + (b) numeric parity at a small D
x = jax.device_put(jax.random.normal(jax.random.PRNGKey(0), (K, 4096)), sh)
assert dim_sharded(x)
key = jax.random.PRNGKey(1)
for name in ("krum", "rfa", "trimmed_mean"):
    agg_s = resolve("aggregator", name, K=K, n_byz=1, sharded=True)
    agg_d = resolve("aggregator", name, K=K, n_byz=1, sharded=False)
    f_s = jax.jit(lambda a, k: agg_s(a, k), in_shardings=(sh, None),
                  out_shardings=NamedSharding(mesh, P("model")))
    got, want = np.asarray(f_s(x, key)), np.asarray(agg_d(x, key))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4), name

# (c) per-device footprint at the reduced-transformer D (compile only)
from repro.configs.base import get_config, reduced
from repro.models.model import init_params
shapes = jax.eval_shape(
    lambda k: init_params(reduced(get_config("qwen2.5-3b")), k),
    jax.random.PRNGKey(0))
D = int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes)))
xs = jax.ShapeDtypeStruct((K, D), jnp.float32)
ks = jax.ShapeDtypeStruct((2,), jnp.uint32)
shard_bytes = K * D * 4 // DEV
for name in ("krum", "rfa"):
    agg_s = resolve("aggregator", name, K=K, n_byz=1, sharded=True)
    f_s = jax.jit(lambda a, k: agg_s(a, k), in_shardings=(sh, None),
                  out_shardings=NamedSharding(mesh, P("model")))
    ma = f_s.lower(xs, ks).compile().memory_analysis()
    assert ma.argument_size_in_bytes <= shard_bytes + 4096, (
        name, ma.argument_size_in_bytes, shard_bytes)
    assert ma.temp_size_in_bytes <= 4 * (shard_bytes + K * K * 4), (
        name, ma.temp_size_in_bytes, shard_bytes)
print("SHARDED_AGG_OK")
"""
    assert "SHARDED_AGG_OK" in _run_subprocess(code)


@pytest.mark.slow
def test_flat_fed_step_matches_tree_step():
    """The flat (K, D) federated step reproduces the tree-sharded step on
    a tiny transformer: same init, same batch, same honest loss, and the
    raveled post-step parameters agree (mean aggregator — identical
    protocol on both routes)."""
    import dataclasses

    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.distributed.fed_trainer import (FedConfig, fed_train_step,
                                               fed_train_step_flat,
                                               init_fed_state,
                                               init_flat_fed_state)
    from jax.flatten_util import ravel_pytree

    cfg = dataclasses.replace(
        reduced(get_config("qwen2.5-3b")), n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16)
    fed = FedConfig(aggregator="mean", kappa=2, n_byz=1,
                    attack="none", lr=1e-3)
    K = 4
    key = jax.random.PRNGKey(0)
    mask = jnp.asarray(np.arange(K) < fed.n_byz)
    batch = TokenPipeline(DataConfig(cfg.vocab_size, 16, 2, K)).batch(0)

    tree_state = init_fed_state(cfg, fed, K, key)
    flat_state, unravel = init_flat_fed_state(cfg, fed, K, key)
    v0, _ = ravel_pytree(jax.tree.map(lambda l: l[0], tree_state.params))
    np.testing.assert_allclose(flat_state.theta[0], v0, atol=1e-6)

    k = jax.random.PRNGKey(7)
    ts, tm = fed_train_step(cfg, fed, tree_state, batch, mask, k,
                            large=True)
    fs, fm = fed_train_step_flat(cfg, fed, flat_state, unravel, batch,
                                 mask, k, large=True)
    np.testing.assert_allclose(float(fm["loss"]), float(tm["loss"]),
                               rtol=1e-5)
    for agent in range(K):
        vt, _ = ravel_pytree(jax.tree.map(lambda l: l[agent],
                                          ts.params))
        np.testing.assert_allclose(fs.theta[agent], vt, atol=1e-5)
