"""keycheck pass: each rule fires on a deliberately-broken jaxpr fixture
(with file/line context pointing into this file) and stays silent on the
sound idioms; the real program inventory is clean."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.keycheck import check_jaxpr, run

KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)
_THIS = "test_analysis_keycheck.py"


def _rules(findings):
    return {f.rule for f in findings}


def _assert_context(findings):
    """Every fixture finding must carry a real location in this file."""
    for f in findings:
        assert f.path.endswith(_THIS), f.format()
        assert f.line > 0, f.format()


# -- broken fixtures --------------------------------------------------------


def test_reused_key_flagged():
    def bad(k):
        return jax.random.normal(k, (2,)) + jax.random.uniform(k, (2,))

    findings = check_jaxpr(jax.make_jaxpr(bad)(KEY), "fixture")
    assert "key-reuse" in _rules(findings)
    _assert_context(findings)


def test_sample_then_derive_flagged():
    def bad(k):
        x = jax.random.normal(k, ())
        k1, _ = jax.random.split(k)
        return x + jax.random.normal(k1, ())

    findings = check_jaxpr(jax.make_jaxpr(bad)(KEY), "fixture")
    assert "sample-then-derive" in _rules(findings)
    _assert_context(findings)


def test_double_split_flagged():
    def bad(k):
        a = jax.random.split(k, 2)
        b = jax.random.split(k, 2)  # identical child streams
        return jax.random.normal(a[0], ()) + jax.random.normal(b[1], ())

    findings = check_jaxpr(jax.make_jaxpr(bad)(KEY), "fixture")
    assert "double-split" in _rules(findings)
    _assert_context(findings)


def test_scan_invariant_sample_flagged():
    def bad(k):
        def body(c, _):
            return c + jax.random.normal(k, ()), None

        return jax.lax.scan(body, 0.0, jnp.arange(3))[0]

    findings = check_jaxpr(jax.make_jaxpr(bad)(KEY), "fixture")
    assert "scan-invariant-sample" in _rules(findings)
    _assert_context(findings)


def test_missing_fanout_flagged():
    def bad(k):
        return jax.random.normal(k, (4,))  # no 4-wide split of the key

    findings = check_jaxpr(jax.make_jaxpr(bad)(KEY), "fixture",
                           expect_fanout=4)
    assert "per-agent-fanout" in _rules(findings)


# -- sound idioms stay clean ------------------------------------------------


def test_split_subkeys_clean():
    def good(k):
        k1, k2 = jax.random.split(k)
        return jax.random.normal(k1, ()) + jax.random.normal(k2, ())

    assert check_jaxpr(jax.make_jaxpr(good)(KEY), "fixture") == []


def test_cond_branches_are_exclusive():
    def good(pred, k):
        return jax.lax.cond(pred,
                            lambda kk: jax.random.normal(kk, ()),
                            lambda kk: jax.random.normal(kk, ()) + 1.0,
                            k)

    closed = jax.make_jaxpr(good)(jax.ShapeDtypeStruct((), jnp.bool_), KEY)
    assert check_jaxpr(closed, "fixture") == []


def test_fold_in_loop_clean():
    def good(k):
        def body(c, t):
            kk = jax.random.fold_in(k, t)
            return c + jax.random.normal(kk, ()), None

        return jax.lax.scan(body, 0.0, jnp.arange(3))[0]

    assert check_jaxpr(jax.make_jaxpr(good)(KEY), "fixture") == []


def test_scan_xs_keys_clean():
    def good(keys):
        def body(c, kk):
            return c + jax.random.normal(kk, ()), None

        return jax.lax.scan(body, 0.0, keys)[0]

    keys = jax.ShapeDtypeStruct((3, 2), jnp.uint32)
    assert check_jaxpr(jax.make_jaxpr(good)(keys), "fixture") == []


def test_vmapped_split_fanout_counts():
    def good(k):
        ks = jax.random.split(k, 4)
        return jax.vmap(lambda kk: jax.random.normal(kk, ()))(ks)

    findings = check_jaxpr(jax.make_jaxpr(good)(KEY), "fixture",
                           expect_fanout=4)
    assert findings == []


# -- the real builders ------------------------------------------------------


@pytest.mark.parametrize("program", [
    "decbyzpg_loop", "byzpg_loop", "lane_batch_loop",
])
def test_rl_programs_clean(program):
    assert run(selected=[program]) == []


@pytest.mark.slow
def test_all_programs_clean():
    assert run() == []
