"""Sharding rules + small-mesh lower/compile (subprocess: the fake-device
XLA flag must be set before jax initializes, so these run out-of-process).
The full production-mesh sweep is ``python -m repro.launch.dryrun --all``
(results in EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_spec_rules():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import param_spec, param_shardings
from repro.models.model import init_params
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2_7b")
shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
sh = param_shardings(cfg, shapes, mesh)
specs = {"/".join(str(getattr(p, "key", p)) for p in path): s.spec
         for path, s in jax.tree_util.tree_flatten_with_path(sh)[0]}
assert specs["embed"] == jax.sharding.PartitionSpec("model", None), specs["embed"]
assert specs["blocks/attn/wq"][-1] == "model"
assert specs["blocks/attn/wo"][-2] == "model"
assert specs["blocks/mlp/w_down"][-2] == "model"
# hymba vocab 32001 not divisible -> replicated embed
cfg2 = get_config("hymba_1_5b")
shapes2 = jax.eval_shape(lambda k: init_params(cfg2, k), jax.random.PRNGKey(0))
sh2 = param_shardings(cfg2, shapes2, mesh)
assert sh2["embed"].spec == jax.sharding.PartitionSpec(None, None)
print("SPEC_OK")
"""
    assert "SPEC_OK" in _run_subprocess(code)


@pytest.mark.slow
def test_small_mesh_train_and_decode_compile():
    """Full system lower+compile on an 8-device (2 data x 4 model... sic:
    2x2x2 multi-pod) mesh for a dense and an MoE arch, exercising the same
    code path as the production dry-run."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.distributed.fed_trainer import FedConfig, make_fed_step
from repro.distributed.serving import make_serve_fns
import dataclasses

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ["llama3_2_1b", "deepseek_v2_lite_16b"]:
    cfg = reduced(get_config(arch))
    cfg = dataclasses.replace(cfg, fed_axis="data")
    fed = FedConfig(aggregator="rfa", kappa=2, n_byz=1)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    step, state_shape, batch, _ = make_fed_step(
        cfg, fed, mesh, large=True, per_agent_batch=2, seq_len=32, key=key)
    K = jax.tree.leaves(state_shape.params)[0].shape[0]
    mask = jax.ShapeDtypeStruct((K,), jnp.bool_)
    compiled = step.lower(state_shape, batch, mask, key).compile()
    assert compiled.cost_analysis()["flops"] > 0
    fns = make_serve_fns(cfg, mesh, batch=4, seq_len=64, key=key)
    tok = jax.ShapeDtypeStruct((4, 1), jnp.int32)
    fns.decode.lower(fns.params_shape, tok, fns.cache_shape).compile()
    print(arch, "COMPILE_OK")
"""
    out = _run_subprocess(code)
    assert out.count("COMPILE_OK") == 2


def test_lane_grid_shards_over_fake_devices():
    """The lane-batched grid on a forced 4-device host mesh: the
    flattened lane×seed batch (4 etas × 2 seeds = 8 rows) divides the
    device count, so `lane_sharding` shards it — and the sharded run
    must reproduce the per-scenario loop's traces on the same machine."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.core.engine import ScenarioGrid, run_grid
from repro.distributed.sharding import lane_mesh, lane_sharding
from repro.rl.envs import make_cartpole

mesh = lane_mesh()
assert mesh is not None and mesh.size == 4, mesh
assert lane_sharding(mesh, 8) is not None     # 8 rows over 4 devices
assert lane_sharding(mesh, 6) is None         # uneven -> identity layout

env = make_cartpole(horizon=10)
grid = ScenarioGrid(seeds=(0, 1),
                    axes={"eta": (1e-3, 5e-3, 1e-2, 2e-2)})
kw = dict(algo="decbyzpg", K=3, n_byz=1, attack="large_noise(sigma=10)",
          N=4, B=2, kappa=1, hidden=(4,))
lanes = run_grid(env, grid, 3, lanes=True, **kw)
per = run_grid(env, grid, 3, lanes=False, **kw)
for scn in per:
    np.testing.assert_allclose(lanes[scn]["returns"],
                               per[scn]["returns"], atol=1e-5)
    np.testing.assert_array_equal(lanes[scn]["samples"],
                                  per[scn]["samples"])
print("LANE_SHARD_OK")
"""
    assert "LANE_SHARD_OK" in _run_subprocess(code)


def test_lane_grid_pads_uneven_rows_onto_mesh():
    """An uneven lane×seed batch (3 lanes × 2 seeds = 6 rows on a
    4-device mesh) pads to the device multiple, shards, and still
    reproduces the per-scenario loop — pad rows never leak into
    summaries."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.core.engine import ScenarioGrid, run_grid
from repro.distributed.sharding import lane_mesh, lane_sharding, padded_rows
from repro.rl.envs import make_cartpole

mesh = lane_mesh()
assert lane_sharding(mesh, 6) is None         # uneven rows can't shard...
assert padded_rows(mesh, 6) == 8              # ...so the grid pads to 8
assert padded_rows(mesh, 8) == 8

env = make_cartpole(horizon=10)
grid = ScenarioGrid(seeds=(0, 1), axes={"eta": (1e-3, 5e-3, 1e-2)})
kw = dict(algo="decbyzpg", K=3, n_byz=1, attack="sign_flip",
          N=4, B=2, kappa=1, hidden=(4,))
lanes = run_grid(env, grid, 3, lanes=True, **kw)
per = run_grid(env, grid, 3, lanes=False, **kw)
for scn in per:
    assert lanes[scn]["returns"].shape == per[scn]["returns"].shape
    np.testing.assert_allclose(lanes[scn]["returns"],
                               per[scn]["returns"], atol=1e-5)
    np.testing.assert_array_equal(lanes[scn]["samples"],
                                  per[scn]["samples"])
print("LANE_PAD_OK")
"""
    assert "LANE_PAD_OK" in _run_subprocess(code)


def test_dryrun_results_if_present():
    """When the production sweep has run, every recorded pair must have
    lowered+compiled OK."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_single_pod.json")
    if not os.path.exists(path):
        pytest.skip("production dry-run sweep not yet executed")
    results = json.load(open(path))
    bad = [f"{r['arch']}/{r['shape']}" for r in results if not r["ok"]]
    assert not bad, bad
    assert len(results) >= 40
