"""RL substrate tests: env invariants, rollout masking, PG estimator
correctness vs finite differences, importance-weight unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.envs import make_cartpole, make_lunarlander
from repro.rl.gradient import (grad_estimate, importance_weights,
                               step_log_probs, weighted_grad_estimate)
from repro.rl.policy import init_mlp, mlp_logits
from repro.rl.rollout import batch_return, sample_batch, sample_trajectory

KEY = jax.random.PRNGKey(0)


def test_cartpole_physics_and_termination():
    env = make_cartpole(horizon=50)
    s = env.reset(KEY)
    assert s.shape == (4,) and bool(jnp.all(jnp.abs(s) <= 0.05))
    # pushing right accelerates the cart right
    s1, r, done = env.step(jnp.zeros(4), jnp.asarray(1))
    assert float(s1[1]) > 0 and float(r) == 1.0 and not bool(done)
    # tilted pole far -> terminal
    s_bad = jnp.array([0.0, 0.0, 0.3, 0.0])
    _, _, done = env.step(s_bad, jnp.asarray(0))
    assert bool(done)


def test_lunarlander_landing_and_crash():
    env = make_lunarlander(horizon=50)
    # gentle touchdown in the pad
    s = jnp.array([0.0, 0.005, 0.0, -0.1, 0.0, 0.0])
    _, r, done = env.step(s, jnp.asarray(0))
    assert bool(done) and float(r) > 50
    # fast crash outside the pad
    s = jnp.array([1.0, 0.005, 0.0, -3.0, 1.0, 0.0])
    _, r, done = env.step(s, jnp.asarray(0))
    assert bool(done) and float(r) < -50


def test_rollout_mask_freezes_after_done():
    env = make_cartpole(horizon=60)
    params = init_mlp(KEY, (4, 8, 2))
    traj = sample_trajectory(env, params, KEY, activation="relu")
    m = np.asarray(traj.mask)
    # mask is non-increasing (once 0, stays 0) and rewards are masked
    assert np.all(np.diff(m) <= 0)
    assert np.all(np.asarray(traj.rewards)[m == 0] == 0)


def test_gpomdp_matches_finite_difference():
    """E[GPOMDP gradient] ~= dJ/dtheta estimated by finite differences on a
    tiny policy (shared fixed action noise => low-variance comparison)."""
    env = make_cartpole(horizon=20)
    params = init_mlp(KEY, (4, 3, 2))
    gamma, M = 0.99, 3000
    keys = jax.random.PRNGKey(42)

    def J(p):
        traj = sample_batch(env, p, keys, M, activation="relu")
        return float(jnp.mean(batch_return(traj, gamma)))

    traj = sample_batch(env, params, keys, M, activation="relu")
    g = grad_estimate(params, traj, gamma, estimator="gpomdp",
                      activation="relu")
    # perturb along the gradient direction: J should increase
    eps = 0.05
    gnorm = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(g)))
    p_up = jax.tree.map(lambda p, gg: p + eps * gg / gnorm, params, g)
    p_dn = jax.tree.map(lambda p, gg: p - eps * gg / gnorm, params, g)
    assert J(p_up) > J(p_dn)


def test_reinforce_and_gpomdp_agree_in_expectation():
    env = make_cartpole(horizon=15)
    params = init_mlp(KEY, (4, 4, 2))
    traj = sample_batch(env, params, KEY, 4000, activation="relu")
    g1 = grad_estimate(params, traj, 0.99, estimator="gpomdp",
                       activation="relu")
    g2 = grad_estimate(params, traj, 0.99, estimator="reinforce",
                       activation="relu")
    v1 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g1)])
    v2 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g2)])
    cos = jnp.dot(v1, v2) / (jnp.linalg.norm(v1) * jnp.linalg.norm(v2))
    assert float(cos) > 0.7


def test_importance_weights_mean_near_one():
    """E_{tau~p(.|theta)}[omega(tau|theta, theta')] = 1."""
    env = make_cartpole(horizon=10)
    params = init_mlp(KEY, (4, 4, 2))
    params_old = jax.tree.map(lambda p: p + 0.01, params)
    traj = sample_batch(env, params, KEY, 4000, activation="relu")
    w = importance_weights(params_old, params, traj, activation="relu")
    assert abs(float(jnp.mean(w)) - 1.0) < 0.05
    assert bool(jnp.all(w > 0))


def test_weighted_grad_estimates_old_policy_gradient():
    """g^omega(tau|theta_old) from tau~theta_new approximates the plain
    gradient at theta_old (SVRPG unbiasedness, App. A.1).

    Variance-reduced comparison (the old horizon-10 form was a known
    seed-baseline failure): at horizon 10 the true gradient of a random
    init is ~0 (‖E g‖ ≈ 0.05 vs per-batch noise ≫ that), so the cosine
    between two *independent* estimates was a coin flip at any feasible
    batch size. At horizon 30 the signal concentrates (‖E g‖ ≈ 5.7;
    independent direct estimates at M=4000 agree to cos > 0.98), and the
    self-normalized IS option removes the realized-weight-mass noise.
    Measured min cosine over seeds 0..9 of this comparison: 0.96.
    """
    env = make_cartpole(horizon=30)
    params_new = init_mlp(KEY, (4, 3, 2))
    params_old = jax.tree.map(lambda p: p * 0.98, params_new)
    k1, k2 = jax.random.split(KEY)
    traj_new = sample_batch(env, params_new, k1, 4000, activation="relu")
    traj_old = sample_batch(env, params_old, k2, 4000, activation="relu")
    g_is = weighted_grad_estimate(params_old, params_new, traj_new, 0.99,
                                  activation="relu", self_normalized=True)
    g_direct = grad_estimate(params_old, traj_old, 0.99, activation="relu")
    v1 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_is)])
    v2 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_direct)])
    cos = jnp.dot(v1, v2) / (jnp.linalg.norm(v1) * jnp.linalg.norm(v2) + 1e-9)
    assert float(cos) > 0.7


def test_self_normalized_is_identity_at_equal_policies():
    """With theta_old == theta_new every weight is 1, so the plain and
    self-normalized IS estimators must both reduce to grad_estimate on
    the same trajectories."""
    env = make_cartpole(horizon=15)
    params = init_mlp(KEY, (4, 3, 2))
    traj = sample_batch(env, params, KEY, 50, activation="relu")
    g = grad_estimate(params, traj, 0.99, activation="relu")
    for sn in (False, True):
        g_is = weighted_grad_estimate(params, params, traj, 0.99,
                                      activation="relu",
                                      self_normalized=sn)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_is)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
