"""memcheck pass: the contract evaluator flags bound violations and
unavailable meshes on fixture contracts, and the real contract table is
clean on the forced-4-device subprocess."""

import pytest

from repro.analysis.memcheck import (MemContract, _check_contracts,
                                     contracts, run)


def _rules(dicts):
    return {d["rule"] for d in dicts}


def test_violated_argument_bound_flagged():
    # an impossible bound: no program's arguments fit in negative bytes
    bad = MemContract(aggregator="rfa", K=8, devices=1,
                      arg_slack=-10**15, temp_factor=10**6)
    found = _check_contracts([bad])
    assert _rules(found) == {"argument-footprint"}
    assert "rfa(K=8)@1dev" in found[0]["message"]


def test_violated_temp_bound_flagged():
    bad = MemContract(aggregator="krum", K=8, devices=1, temp_factor=0)
    found = _check_contracts([bad])
    assert "temp-footprint" in _rules(found)


def test_unavailable_mesh_flagged():
    bad = MemContract(aggregator="rfa", K=8, devices=4096)
    found = _check_contracts([bad])
    assert _rules(found) == {"mesh-unavailable"}


def test_contract_table_shape():
    table = contracts()
    assert {c.devices for c in table} == {2, 4}
    assert {c.aggregator for c in table} == {"krum", "rfa"}


@pytest.mark.slow
def test_real_contracts_clean():
    # full path: forced-device subprocess + JSON findings protocol
    assert run() == []
