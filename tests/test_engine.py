"""Fused experiment engine tests: scan-vs-legacy equivalence, scenario
grids (shapes, determinism, seed-vmap), compiled-loop cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.byzpg import ByzPGConfig, run_byzpg, run_byzpg_legacy
from repro.core.decbyzpg import (DecByzPGConfig, run_decbyzpg,
                                 run_decbyzpg_legacy)
from repro.core.engine import Scenario, ScenarioGrid, run_grid
from repro.rl.envs import make_cartpole

ENV = make_cartpole(horizon=20)
T = 5


def tiny_dec(**kw):
    base = dict(K=3, n_byz=1, attack="sign_flip", aggregator="rfa",
                agreement="gda", kappa=2, N=4, B=2, eta=1e-2,
                hidden=(8,), seed=11)
    base.update(kw)
    return DecByzPGConfig(**base)


def test_fused_scan_matches_legacy_decbyzpg():
    """The fused lax.scan loop and the per-step dispatch loop run the same
    step function over the same key/coin streams: the return, sample, and
    diameter traces must coincide."""
    cfg = tiny_dec()
    fused = run_decbyzpg(ENV, cfg, T)
    legacy = run_decbyzpg_legacy(ENV, cfg, T)
    np.testing.assert_allclose(fused["returns"], legacy["returns"],
                               atol=1e-5)
    np.testing.assert_allclose(fused["diameter"], legacy["diameter"],
                               atol=1e-6)
    np.testing.assert_array_equal(fused["samples"], legacy["samples"])
    np.testing.assert_allclose(fused["theta"], legacy["theta"], atol=1e-6)


def test_fused_scan_matches_legacy_byzpg():
    cfg = ByzPGConfig(K=3, n_byz=1, attack="large_noise", aggregator="rfa",
                      N=4, B=2, eta=1e-2, hidden=(8,), seed=5)
    fused = run_byzpg(ENV, cfg, T)
    legacy = run_byzpg_legacy(ENV, cfg, T)
    np.testing.assert_allclose(fused["returns"], legacy["returns"],
                               atol=1e-5)
    np.testing.assert_array_equal(fused["samples"], legacy["samples"])


def test_coin_stream_first_step_large_and_reproducible():
    cfg = tiny_dec()
    out = run_decbyzpg(ENV, cfg, T)
    # t=0 is forced to a large step (Algorithm 1/2 line 1)
    assert out["samples"][0] == cfg.N
    again = run_decbyzpg(ENV, cfg, T)
    np.testing.assert_array_equal(out["returns"], again["returns"])


def _grid(seeds=(0, 1, 2)):
    return ScenarioGrid(seeds=seeds, K=(3,), n_byz=(1,),
                        attack=("sign_flip", "large_noise"),
                        aggregator=("rfa", "mean"), agreement=("gda",))


GRID_KW = dict(N=4, B=2, eta=1e-2, kappa=2, hidden=(8,))
GRID_KW_NOETA = dict(N=4, B=2, kappa=2, hidden=(8,))


def test_run_grid_shapes():
    """(3 seeds) x (2 attacks) x (2 aggregators) in ONE call, seeds
    vmapped inside each scenario's compiled program."""
    res = run_grid(ENV, _grid(), T, algo="decbyzpg", **GRID_KW)
    assert len(res) == 4
    scn = Scenario(3, 1, "sign_flip", "rfa", "gda")
    assert scn in res
    out = res[scn]
    assert out["returns"].shape == (3, T)
    assert out["diameter"].shape == (3, T)
    assert out["samples"].shape == (3, T)
    assert out["returns_mean"].shape == (T,)
    assert out["returns_ci95"].shape == (T,)
    assert np.isfinite(out["final_return_mean"])
    assert out["final_return_ci95"] >= 0.0
    # every lane starts with the forced large step
    np.testing.assert_array_equal(out["samples"][:, 0],
                                  np.full(3, GRID_KW["N"]))
    # distinct seeds produce distinct trajectories
    assert not np.array_equal(out["returns"][0], out["returns"][1])


def test_run_grid_deterministic_and_cached():
    a = run_grid(ENV, _grid(), T, algo="decbyzpg", **GRID_KW)
    n_compiled = engine.compile_count()
    b = run_grid(ENV, _grid(), T, algo="decbyzpg", **GRID_KW)
    assert engine.compile_count() == n_compiled     # loop cache reused
    for scn in a:
        np.testing.assert_array_equal(a[scn]["returns"], b[scn]["returns"])
        np.testing.assert_array_equal(a[scn]["diameter"],
                                      b[scn]["diameter"])


def test_grid_lane_matches_single_run():
    """A grid lane for seed s replays run_decbyzpg(cfg(seed=s)) exactly
    (same canonical key split, coin stream, and step math under vmap)."""
    cfg = tiny_dec(seed=2, attack="sign_flip", aggregator="rfa")
    single = run_decbyzpg(ENV, cfg, T)
    res = run_grid(ENV, ScenarioGrid(seeds=(2,), K=(3,), n_byz=(1,),
                                     attack=("sign_flip",),
                                     aggregator=("rfa",),
                                     agreement=("gda",)),
                   T, algo="decbyzpg", **GRID_KW)
    out = res[Scenario(3, 1, "sign_flip", "rfa", "gda")]
    np.testing.assert_allclose(out["returns"][0], single["returns"],
                               atol=1e-5)
    np.testing.assert_array_equal(out["samples"][0], single["samples"])


def test_run_grid_byzpg():
    res = run_grid(ENV, ScenarioGrid(seeds=(0, 1), K=(3,), n_byz=(1,),
                                     attack=("large_noise",),
                                     aggregator=("rfa", "mean")),
                   T, algo="byzpg", N=4, B=2, eta=1e-2, hidden=(8,))
    assert len(res) == 2
    for out in res.values():
        assert out["returns"].shape == (2, T)
        assert np.all(np.isfinite(out["returns"]))


def test_fed_train_window_matches_per_step():
    """The fused fed window (lax.scan + traced-coin lax.cond) replays the
    per-step driver exactly when fed the same key/coin streams."""
    from repro.configs.base import get_config, reduced
    from repro.distributed.fed_trainer import (FedConfig, fed_coin_key,
                                               fed_train_step,
                                               fed_train_window,
                                               init_fed_state)
    cfg = reduced(get_config("qwen2_5_3b"))
    K, W = 2, 4
    fed = FedConfig(aggregator="mean", kappa=0, lr=2e-3, page_p=0.5, seed=1)
    key0 = jax.random.PRNGKey(0)
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(t),
                                             (K, 2, 16), 0, cfg.vocab_size),
                "labels": jax.random.randint(jax.random.PRNGKey(100 + t),
                                             (K, 2, 16), 0, cfg.vocab_size)}
               for t in range(W)]
    mask = jnp.zeros((K,), bool)
    k_loop = jax.random.PRNGKey(42)

    state_a = init_fed_state(cfg, fed, K, key0)
    state_a, metrics = fed_train_window(cfg, fed, state_a,
                                        jax.tree.map(
                                            lambda *xs: jnp.stack(xs),
                                            *batches),
                                        mask, jnp.arange(W), k_loop)

    state_b = init_fed_state(cfg, fed, K, key0)
    coins, losses = [], []
    for t in range(W):
        coin = bool(engine.page_coin(fed_coin_key(fed), t, fed.page_p))
        coins.append(coin)
        state_b, m = fed_train_step(cfg, fed, state_b, batches[t], mask,
                                    jax.random.fold_in(k_loop, t),
                                    large=coin)
        losses.append(float(m["loss"]))

    assert coins[0] is True                       # forced large at t=0
    assert not all(coins)                         # PAGE branch exercised
    np.testing.assert_array_equal(np.asarray(metrics["coin"]), coins)
    np.testing.assert_allclose(np.asarray(metrics["loss"]), losses,
                               rtol=1e-5, atol=1e-6)
    # Adam divides near-zero second moments into cross-compilation float
    # noise, so params only match to a fraction of the lr per step; a
    # mis-wired coin branch would diverge at full lr scale instead.
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_grid_override_adjusts_config():
    """override() derives per-scenario fields from axis values (fig2's
    kappa=0 naive baseline)."""
    seen = {}

    def override(cfg):
        cfg = dataclasses.replace(
            cfg, kappa=0 if cfg.aggregator.name == "mean" else 2)
        seen[cfg.aggregator.name] = cfg.kappa
        return cfg

    run_grid(ENV, ScenarioGrid(seeds=(0,), K=(3,), n_byz=(0,),
                               aggregator=("rfa", "mean"),
                               agreement=("gda",)),
             T, algo="decbyzpg", override=override, **GRID_KW)
    assert seen == {"rfa": 2, "mean": 0}


def test_grid_override_mutating_axis_raises():
    """An override that rewrites a swept axis field would silently diverge
    from the Scenario key — it must raise instead."""
    import pytest
    with pytest.raises(ValueError, match="aggregator"):
        run_grid(ENV, ScenarioGrid(seeds=(0,), K=(3,), n_byz=(0,),
                                   aggregator=("rfa", "mean"),
                                   agreement=("gda",)),
                 T, algo="decbyzpg",
                 override=lambda c: dataclasses.replace(c,
                                                        aggregator="cwmed"),
                 **GRID_KW)


# ---------------------------------------------------------------------------
# Arbitrary sweep axes + the declarative Experiment API
# ---------------------------------------------------------------------------


def test_run_grid_arbitrary_axes():
    """Axes sweep any config field — here eta × a parameterized attack
    spec — and results key by the grid's own axis tuple."""
    grid = ScenarioGrid(seeds=(0, 1),
                        axes={"eta": (1e-2, 5e-3),
                              "attack": ("none", "large_noise(sigma=10)")})
    res = run_grid(ENV, grid, T, algo="decbyzpg",
                   K=3, n_byz=1, N=4, B=2, kappa=2, hidden=(8,))
    assert len(res) == 4
    out = res[(1e-2, "large_noise(sigma=10)")]     # tuple-equality lookup
    assert out["returns"].shape == (2, T)
    assert np.all(np.isfinite(out["returns"]))
    n = engine.compile_count()
    res2 = run_grid(ENV, grid, T, algo="decbyzpg",
                    K=3, n_byz=1, N=4, B=2, kappa=2, hidden=(8,))
    assert engine.compile_count() == n              # cache hit on repeat
    for scn in res:
        np.testing.assert_array_equal(res[scn]["returns"],
                                      res2[scn]["returns"])


def test_run_grid_unknown_axis_raises():
    import pytest
    with pytest.raises(TypeError, match="not_a_field"):
        run_grid(ENV, ScenarioGrid(seeds=(0,), axes={"not_a_field": (1,)}),
                 T, algo="decbyzpg", **GRID_KW)
    with pytest.raises(TypeError, match="swept and fixed"):
        run_grid(ENV, ScenarioGrid(seeds=(0,), axes={"eta": (1e-2,)}),
                 T, algo="decbyzpg", eta=2e-2, K=3, N=4, B=2, hidden=(8,))


def test_run_grid_base_pins_legacy_default_axis():
    """A base kwarg naming an axis the grid only holds as a legacy default
    pins that axis to the base value (and keys it accordingly), instead of
    the default silently winning."""
    res = run_grid(ENV, ScenarioGrid(seeds=(0,)), T, algo="decbyzpg",
                   K=3, N=4, B=2, kappa=1, hidden=(8,))
    (scn,) = res
    assert scn.K == 3 and scn.aggregator == "rfa"
    assert res[scn]["returns"].shape == (1, T)


def test_experiment_end_to_end(tmp_path):
    from repro.core.engine import Experiment
    exp = Experiment(algo="decbyzpg", env="cartpole(horizon=20)", T=T,
                     seeds=2,
                     axes={"aggregator": ("rfa", "mean")},
                     K=3, n_byz=1, attack="sign_flip", N=4, B=2, kappa=2,
                     hidden=(8,))
    res = exp.run()
    assert len(res) == 2
    robust = res.sel(aggregator="rfa")
    assert robust["returns"].shape == (2, T)
    # run() caches; run(force=True) re-executes identically
    assert exp.run() is res
    res2 = exp.run(force=True)
    np.testing.assert_array_equal(robust["returns"],
                                  res2.sel(aggregator="rfa")["returns"])
    # summary + JSON
    summ = exp.summary()
    assert set(summ) == {"aggregator=rfa", "aggregator=mean"}
    path = tmp_path / "exp.json"
    doc = exp.to_json(path)
    assert path.exists()
    assert doc["experiment"]["algo"] == "decbyzpg"
    assert {d["scenario"]["aggregator"] for d in doc["scenarios"]} == \
        {"rfa", "mean"}
    assert all(len(d["returns_mean"]) == T for d in doc["scenarios"])


def test_experiment_no_axes_single_scenario():
    from repro.core.engine import Experiment
    exp = Experiment(algo="byzpg", env="cartpole(horizon=20)", T=T,
                     seeds=(0,), K=3, N=4, B=2, hidden=(8,))
    res = exp.run()
    assert len(res) == 1
    (out,) = res.results.values()
    assert out["returns"].shape == (1, T)
    assert "base" in exp.summary()


# ---------------------------------------------------------------------------
# Lane batching: static/traced split, equivalence, compile counts
# ---------------------------------------------------------------------------


def test_lane_split_static_traced():
    """Scenarios differing only in traced scalars (eta, a batchable attack
    sigma, an explicit p equal to the B/N default) share one static
    representative; the traced vector carries the per-lane values."""
    from repro.core.registry import Spec
    a = engine._algo("decbyzpg")
    cfg1 = tiny_dec(eta=1e-2, attack="large_noise(sigma=10)", seed=3)
    cfg2 = tiny_dec(eta=5e-3, attack="large_noise(sigma=50)", seed=7)
    cfg3 = tiny_dec(eta=1e-2, attack="large_noise", p=0.5)   # p = B/N
    s1, n1, v1 = engine.lane_split(cfg1, a.traced_fields)
    s2, n2, v2 = engine.lane_split(cfg2, a.traced_fields)
    s3, n3, v3 = engine.lane_split(cfg3, a.traced_fields)
    assert s1 == s2 == s3 and n1 == n2 == n3
    assert s1.attack == Spec("large_noise") and s1.seed == 0
    assert s1.p is None
    tr1, tr2, tr3 = (dict(zip(n, v)) for n, v in
                     ((n1, v1), (n2, v2), (n3, v3)))
    assert tr1["eta"] == 1e-2 and tr2["eta"] == 5e-3
    assert tr1["attack.sigma"] == 10.0 and tr2["attack.sigma"] == 50.0
    assert tr3["attack.sigma"] == 100.0        # factory default filled in
    assert tr1["switch_p"] == 0.5 and tr3["switch_p"] == 0.5
    # a non-traced difference (K) changes the static signature
    s4, _, _ = engine.lane_split(tiny_dec(K=4, n_byz=1),
                                 a.traced_fields)
    assert s4 != s1


def test_lane_grid_matches_per_scenario():
    """The lane-batched grid replays the per-scenario loop on the same
    seed_keys streams — honest and attacked configs — trace for trace."""
    grid = ScenarioGrid(
        seeds=(0, 1),
        axes={"eta": (1e-2, 5e-3),
              "attack": ("none", "large_noise(sigma=10)")})
    kw = dict(algo="decbyzpg", K=3, n_byz=1, N=4, B=2, kappa=2,
              hidden=(8,))
    lanes = run_grid(ENV, grid, T, lanes=True, **kw)
    per = run_grid(ENV, grid, T, lanes=False, **kw)
    assert list(map(tuple, lanes)) == list(map(tuple, per))
    for scn in per:
        np.testing.assert_allclose(lanes[scn]["returns"],
                                   per[scn]["returns"], atol=1e-5)
        np.testing.assert_array_equal(lanes[scn]["samples"],
                                      per[scn]["samples"])
        np.testing.assert_allclose(lanes[scn]["diameter"],
                                   per[scn]["diameter"], atol=1e-3)
        np.testing.assert_allclose(np.asarray(lanes[scn]["theta"]),
                                   np.asarray(per[scn]["theta"]),
                                   atol=1e-5)


def test_lane_grid_matches_per_scenario_byzpg():
    grid = ScenarioGrid(seeds=(0, 1), axes={"eta": (1e-2, 2e-2)})
    kw = dict(algo="byzpg", K=3, n_byz=1, attack="sign_flip",
              N=4, B=2, hidden=(8,))
    lanes = run_grid(ENV, grid, T, lanes=True, **kw)
    per = run_grid(ENV, grid, T, lanes=False, **kw)
    for scn in per:
        np.testing.assert_allclose(lanes[scn]["returns"],
                                   per[scn]["returns"], atol=1e-5)
        np.testing.assert_array_equal(lanes[scn]["samples"],
                                      per[scn]["samples"])


def test_lane_grid_compile_count():
    """A scalar sweep is ONE compiled program per static signature: a
    6-point eta × 4-seed grid adds exactly one compiled-loop cache entry;
    adding a shape axis (K) adds one entry per K value, not per combo."""
    kw = dict(algo="decbyzpg", N=4, B=2, kappa=1, hidden=(8,))
    engine.clear_cache()
    run_grid(ENV, ScenarioGrid(
        seeds=(0, 1, 2, 3),
        axes={"eta": (1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2)}),
        T, K=3, **kw)
    assert engine.compile_count() == 1
    engine.clear_cache()
    run_grid(ENV, ScenarioGrid(
        seeds=(0, 1), axes={"eta": (1e-2, 2e-2), "K": (3, 4)}),
        T, **kw)
    assert engine.compile_count() == 2
    # re-running the same grid reuses both programs
    run_grid(ENV, ScenarioGrid(
        seeds=(0, 1), axes={"eta": (1e-2, 2e-2), "K": (3, 4)}),
        T, **kw)
    assert engine.compile_count() == 2


def test_lane_grid_lane_matches_single_run():
    """A lane inside a lane-batched sweep replays run_decbyzpg for the
    matching (config, seed) exactly like a per-scenario grid lane does."""
    cfg = tiny_dec(seed=2, eta=5e-3)
    single = run_decbyzpg(ENV, cfg, T)
    res = run_grid(ENV, ScenarioGrid(seeds=(2,),
                                     axes={"eta": (1e-2, 5e-3)}),
                   T, algo="decbyzpg", K=3, n_byz=1, attack="sign_flip",
                   aggregator="rfa", agreement="gda", **GRID_KW_NOETA)
    out = res[(5e-3,)]
    np.testing.assert_allclose(out["returns"][0], single["returns"],
                               atol=1e-5)
    np.testing.assert_array_equal(out["samples"][0], single["samples"])


# ---------------------------------------------------------------------------
# ExperimentResult.sel diagnostics + Spec-stable scenario names
# ---------------------------------------------------------------------------


def _fake_result():
    from repro.core.engine import ExperimentResult, scenario_key
    from repro.core.registry import Spec
    axes = {"eta": (1e-2, 2e-2),
            "attack": (Spec.of("none"), Spec.of("large_noise(sigma=10)"))}
    key_cls = scenario_key(axes)
    results = {key_cls(e, a): {"scn": (e, a)}
               for e in axes["eta"] for a in axes["attack"]}
    return ExperimentResult({}, axes, results)


def test_sel_underspecified_names_free_axes():
    import pytest
    res = _fake_result()
    with pytest.raises(KeyError, match="under-specified") as ei:
        res.sel(eta=1e-2)
    msg = str(ei.value)
    assert "attack" in msg and "large_noise(sigma=10)" in msg
    # the scenario-tuple dump of the old error is gone
    assert "Scenario(" not in msg
    with pytest.raises(KeyError, match="matches no scenario"):
        res.sel(eta=3.0)
    with pytest.raises(KeyError, match="not sweep axes"):
        res.sel(bogus=1)


def test_sel_spec_string_interchangeable():
    from repro.core.registry import Spec
    res = _fake_result()
    out = res.sel(eta=1e-2, attack="large_noise(sigma=10)")
    assert out["scn"][1] == Spec.of("large_noise(sigma=10)")
    out2 = res.sel(eta=1e-2, attack=Spec.of("large_noise(sigma=10)"))
    assert out2 is out


def test_scenario_name_canonical_for_specs():
    from repro.core.engine import ExperimentResult, scenario_key
    from repro.core.registry import Spec
    key_cls = scenario_key(("attack", "eta"))
    scn_spec = key_cls(Spec.of("large_noise(sigma=10)"), 1e-2)
    scn_str = key_cls("large_noise(sigma=10)", 1e-2)
    name = ExperimentResult.scenario_name(scn_spec)
    assert name == ExperimentResult.scenario_name(scn_str)
    assert "Spec(" not in name and "large_noise(sigma=10)" in name


def test_experiment_matches_run_grid():
    """The declarative front door executes through the same grid engine:
    identical keys and traces for an equivalent legacy-style call."""
    from repro.core.engine import Experiment
    legacy = run_grid(ENV, _grid(seeds=(0, 1)), T, algo="decbyzpg",
                      **GRID_KW)
    exp = Experiment(algo="decbyzpg", env="cartpole(horizon=20)", T=T,
                     seeds=(0, 1),
                     axes={"K": (3,), "n_byz": (1,),
                           "attack": ("sign_flip", "large_noise"),
                           "aggregator": ("rfa", "mean"),
                           "agreement": ("gda",)},
                     **GRID_KW)
    res = exp.run()
    assert set(map(tuple, res.keys())) == set(map(tuple, legacy.keys()))
    for scn in legacy:
        np.testing.assert_array_equal(res[tuple(scn)]["returns"],
                                      legacy[scn]["returns"])


def test_lane_split_traced_aggregator_kwargs():
    """Aggregator hyperparameters declared traced (rfa.nu,
    centered_clip.tau) batch into lanes exactly like attack.sigma:
    configs differing only in the kwarg share one static representative
    and the traced vector carries the per-lane values (factory default
    filled in when the spec omits the kwarg)."""
    from repro.core.registry import Spec
    a = engine._algo("decbyzpg")
    cfg1 = tiny_dec(aggregator="rfa(nu=1e-6)")
    cfg2 = tiny_dec(aggregator="rfa(nu=1e-2)")
    cfg3 = tiny_dec(aggregator="rfa")
    s1, n1, v1 = engine.lane_split(cfg1, a.traced_fields)
    s2, n2, v2 = engine.lane_split(cfg2, a.traced_fields)
    s3, n3, v3 = engine.lane_split(cfg3, a.traced_fields)
    assert s1 == s2 == s3 and n1 == n2 == n3
    assert s1.aggregator == Spec("rfa")
    tr1, tr2, tr3 = (dict(zip(n, v)) for n, v in
                     ((n1, v1), (n2, v2), (n3, v3)))
    assert tr1["aggregator.nu"] == 1e-6 and tr2["aggregator.nu"] == 1e-2
    assert tr3["aggregator.nu"] == 1e-6          # factory default
    # centered_clip.tau takes the same path
    sa, na, va = engine.lane_split(
        tiny_dec(aggregator="centered_clip(tau=0.5)"), a.traced_fields)
    sb, nb, vb = engine.lane_split(
        tiny_dec(aggregator="centered_clip(tau=2.0)"), a.traced_fields)
    assert sa == sb and sa.aggregator == Spec("centered_clip")
    assert dict(zip(na, va))["aggregator.tau"] == 0.5
    assert dict(zip(nb, vb))["aggregator.tau"] == 2.0
    # a static aggregator kwarg (n_iter) still splits the signature
    sc, _, _ = engine.lane_split(tiny_dec(aggregator="rfa(n_iter=8)"),
                                 a.traced_fields)
    assert sc != s1


def test_lane_grid_aggregator_kwarg_sweep_compiles_once():
    """A robustness sweep over rfa's smoothing nu is ONE compiled program,
    and each lane matches its per-scenario run."""
    grid = ScenarioGrid(
        seeds=(0, 1),
        axes={"aggregator": ("rfa(nu=1e-6)", "rfa(nu=1e-3)",
                             "rfa(nu=1e-1)")})
    kw = dict(algo="decbyzpg", K=3, n_byz=1, attack="sign_flip",
              agreement="gda", kappa=2, N=4, B=2, hidden=(8,))
    engine.clear_cache()
    lanes = run_grid(ENV, grid, T, lanes=True, **kw)
    assert engine.compile_count() == 1
    per = run_grid(ENV, grid, T, lanes=False, **kw)
    for scn in per:
        np.testing.assert_allclose(lanes[scn]["returns"],
                                   per[scn]["returns"], atol=1e-5)


def test_lane_grid_attack_kwarg_sweeps_compile_once():
    """Every traced attack knob batches: a sign_flip scale sweep and an
    alie z sweep each collapse to one compiled program per attack name,
    lane-for-lane equal to the per-scenario dispatch."""
    for axis in (("sign_flip(scale=1.0)", "sign_flip(scale=3.0)",
                  "sign_flip(scale=5.0)"),
                 ("alie(z=0.5)", "alie(z=1.5)", "alie(z=3.0)")):
        grid = ScenarioGrid(seeds=(0, 1), axes={"attack": axis})
        kw = dict(algo="decbyzpg", K=3, n_byz=1, aggregator="rfa",
                  agreement="gda", kappa=2, N=4, B=2, hidden=(8,))
        engine.clear_cache()
        lanes = run_grid(ENV, grid, T, lanes=True, **kw)
        assert engine.compile_count() == 1, axis
        per = run_grid(ENV, grid, T, lanes=False, **kw)
        for scn in per:
            np.testing.assert_allclose(lanes[scn]["returns"],
                                       per[scn]["returns"], atol=1e-5)
            np.testing.assert_array_equal(lanes[scn]["samples"],
                                          per[scn]["samples"])


def test_registry_kwarg_audit_is_exhaustive():
    """Every numeric factory kwarg in the sweepable namespaces is
    deliberately classified traced (lane-batchable) or static (program
    shape) — an unclassified scalar would silently split lane groups."""
    import repro.distributed.aggregation  # noqa: F401  registers fed_*
    from repro.core.registry import REGISTRY
    for ns in ("attack", "aggregator", "fed_attack", "fed_aggregator"):
        assert REGISTRY.unclassified_kwargs(ns) == {}, ns
    # spot-check the split: bucketing's s reshapes (static), its traced
    # set stays empty; sign_flip's scale is data (traced)
    assert "s" in REGISTRY.meta("aggregator", "bucketing")["static_kwargs"]
    assert "scale" in REGISTRY.meta("attack", "sign_flip")["traced_kwargs"]


# ---------------------------------------------------------------------------
# Windowed execution (sweep service, DESIGN.md §12)
# ---------------------------------------------------------------------------


def _chain_windows(env, static_cfg, names, T_, slices, n_rows, algo,
                   vals_flat, seeds_flat):
    init = engine.lane_init_loop(env, static_cfg, n_rows, algo)
    carry = init(seeds_flat)
    chunks = []
    for start, stop in slices:
        win = engine.lane_window_loop(env, static_cfg, T_, names,
                                      stop - start, n_rows, algo)
        carry, ch = win(carry, vals_flat, seeds_flat,
                        np.arange(start, stop))
        chunks.append(ch)
    return engine.assemble_hist(carry, chunks, algo)


def _windowed_vs_oneshot(algo, cfg_kw, axes):
    import jax.numpy as jnp
    grid = ScenarioGrid(seeds=(0, 1), axes=axes)
    _, scenarios = engine.grid_scenarios(grid, algo=algo, base=cfg_kw)
    ((static_cfg, names), members), = \
        engine.lane_groups(scenarios, algo=algo).items()
    n_rows = len(members) * 2
    vals_flat, seeds_flat = engine.lane_operands(
        members, jnp.asarray(grid.seeds, jnp.int32), n_rows)
    one = engine.lane_batch_loop(ENV, static_cfg, T, names, n_rows, algo)
    ref = {k: np.asarray(v)
           for k, v in one(vals_flat, seeds_flat).items()}
    got = _chain_windows(ENV, static_cfg, names, T,
                         engine.window_slices(T, 3), n_rows, algo,
                         vals_flat, seeds_flat)
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)


def test_lane_windows_chain_bit_identical_decbyzpg():
    """Chaining the window programs over window_slices replays the fused
    one-shot lane scan bit for bit — same key stream, same carry, same
    history — for honest and attacked lanes."""
    _windowed_vs_oneshot(
        "decbyzpg",
        dict(K=3, n_byz=1, N=4, B=2, kappa=2, hidden=(8,)),
        {"eta": (1e-2, 5e-3),
         "attack": ("large_noise(sigma=10)", "large_noise(sigma=50)")})


def test_lane_windows_chain_bit_identical_byzpg():
    _windowed_vs_oneshot(
        "byzpg",
        dict(K=3, n_byz=1, attack="sign_flip", N=4, B=2, hidden=(8,)),
        {"eta": (1e-2, 2e-2)})


def test_lane_window_cache_key_is_offset_free():
    """Equal-width windows of one run share a single compiled program
    (the window's absolute indices are traced data, not a cache-key
    offset): T=5 in W=5 width-1 windows compiles exactly one init + one
    window entry for five dispatches."""
    cfg_kw = dict(K=3, n_byz=1, attack="sign_flip", aggregator="rfa",
                  agreement="gda", kappa=2, N=4, B=2, hidden=(8,))
    grid = ScenarioGrid(seeds=(0, 1), axes={"eta": (1e-2, 5e-3)})
    _, scenarios = engine.grid_scenarios(grid, algo="decbyzpg",
                                         base=cfg_kw)
    ((static_cfg, names), members), = \
        engine.lane_groups(scenarios, algo="decbyzpg").items()
    vals_flat, seeds_flat = engine.lane_operands(
        members, jnp.asarray(grid.seeds, jnp.int32), 4)
    engine.clear_cache()
    _chain_windows(ENV, static_cfg, names, T, engine.window_slices(T, T),
                   4, "decbyzpg", vals_flat, seeds_flat)
    assert engine.compile_count() == 2      # lanes_init + one lanes_window


def test_seed_windows_chain_matches_seed_batch_loop():
    """The per-scenario (lanes=False) windowed pair reproduces
    seed_batch_loop exactly, uneven window widths included (T=5, W=2
    -> widths 3 and 2)."""
    cfg = tiny_dec(seed=0)
    seeds = jnp.asarray([0, 1, 2], jnp.int32)
    ref = {k: np.asarray(v) for k, v in
           engine.seed_batch_loop(ENV, cfg, T, 3)(seeds).items()}
    carry = engine.seed_init_loop(ENV, cfg, 3)(seeds)
    chunks = []
    for start, stop in engine.window_slices(T, 2):
        win = engine.seed_window_loop(ENV, cfg, T, stop - start, 3)
        carry, ch = win(carry, seeds, np.arange(start, stop))
        chunks.append(ch)
    got = engine.assemble_hist(carry, chunks, "decbyzpg")
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)


def test_lane_carry_struct_matches_init_loop():
    """The eval_shape skeleton names the same leaves/shapes/dtypes as the
    real init program's output — the sweep-resume restore contract."""
    cfg = tiny_dec()
    a = engine._algo("decbyzpg")
    static_cfg, _, _ = engine.lane_split(cfg, a.traced_fields)
    struct = engine.lane_carry_struct(ENV, static_cfg, 4, "decbyzpg")
    real = engine.lane_init_loop(ENV, static_cfg, 4, "decbyzpg")(
        jnp.arange(4, dtype=jnp.int32))
    s_flat = jax.tree_util.tree_flatten(struct)[0]
    r_flat, r_def = jax.tree_util.tree_flatten(real)
    assert jax.tree_util.tree_structure(struct) == r_def
    for s, r in zip(s_flat, r_flat):
        assert tuple(s.shape) == tuple(r.shape)
        assert s.dtype == r.dtype


def test_pad_rows_repeats_last_row_and_slices_clean():
    x = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
    padded = engine._pad_rows(x, 5)
    assert padded.shape == (5, 2)
    np.testing.assert_array_equal(np.asarray(padded[:3]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(padded[3:]),
                                  np.tile(np.asarray(x[-1]), (2, 1)))
    assert engine._pad_rows(x, 3) is x      # no-op when already aligned
