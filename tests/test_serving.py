"""Serving subsystem: batching invariance, slot lifecycle boundaries,
zero-recompile guarantee, the ServeFns shim, and the public API surface.

The load-bearing contract is *batching invariance*: the tokens a request
receives must not depend on how many slots the engine has, which slot it
landed in, or what other requests were in flight — continuous batching
is a scheduling optimization, never a numerics change.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.core.registry import resolve
from repro.rl.envs import make_env
from repro.serving import (DecodeEngine, PolicyServer, Request,
                           SlotScheduler, engine_for_policy, make_traffic)


@pytest.fixture(scope="module")
def env():
    return make_env("cartpole(horizon=16)")


@pytest.fixture(scope="module")
def policy(env):
    return resolve(
        "policy",
        "transformer(arch='qwen2.5-3b', n_layers=2, d_model=64, "
        "n_heads=2)", env=env)


@pytest.fixture(scope="module")
def params(policy):
    return policy.init(jax.random.PRNGKey(42))


def _tokens_by_uid(policy, params, traffic, slots, **kw):
    eng = engine_for_policy(policy, params, slots=slots, max_new=8,
                            max_prompt=4, **kw)
    report = PolicyServer(eng, warmup=False).run_offline(traffic)
    assert len(report.results) == len(traffic)
    return {r.uid: r.tokens for r in report.results}


def test_slot_count_invariance(policy, params, env):
    """Same stream, 1 vs 2 vs 4 slots: identical greedy tokens per uid."""
    traffic = make_traffic(10, seed=7, rate_rps=500.0, max_new=8,
                           obs_dim=env.obs_dim)
    t1 = _tokens_by_uid(policy, params, traffic, slots=1)
    t2 = _tokens_by_uid(policy, params, traffic, slots=2)
    t4 = _tokens_by_uid(policy, params, traffic, slots=4)
    assert t1 == t2 == t4
    # degenerate streams (all-identical tokens) can't catch cross-slot
    # leakage — the fixture params must produce varied outputs
    assert any(len(set(t)) > 1 for t in t1.values())


def test_arrival_order_invariance(policy, params, env):
    """Admission order must not change any request's tokens."""
    traffic = make_traffic(8, seed=3, rate_rps=500.0, max_new=8,
                           obs_dim=env.obs_dim)
    base = _tokens_by_uid(policy, params, traffic, slots=3)
    rng = np.random.default_rng(0)
    for _ in range(2):
        shuffled = list(traffic)
        rng.shuffle(shuffled)
        for i, r in enumerate(shuffled):    # arrival stamps follow order
            r.arrival_s = i * 1e-3
        assert _tokens_by_uid(policy, params, shuffled, slots=3) == base


def test_prompt_padding_invariance(policy, params):
    """A bucketed (padded) prefill must yield the same tokens as an
    exact-length prefill: padded ring entries are invalidated on insert."""
    req = [Request(uid=0, max_new=6, tokens=np.array([3, 1, 2], np.int32))]

    def run(buckets):
        eng = DecodeEngine(policy.model_cfg, params, slots=1, max_new=6,
                           max_prompt=8, prompt_buckets=buckets,
                           n_logits=None)
        sch = SlotScheduler(eng)
        assert sch.admit(req[0]) is None
        (res,) = sch.drain()
        return res.tokens

    assert run(buckets=(3,)) == run(buckets=(8,))


def test_matches_unbatched_reference(policy, params, env):
    """Engine output == the seed-era prefill + decode_step loop, exactly."""
    import jax.numpy as jnp
    from repro.models.model import decode_step, prefill

    cfg = policy.model_cfg
    obs_v = np.linspace(-0.5, 0.5, env.obs_dim).astype(np.float32)
    max_new = 6

    # reference: batch-1, exact length, scalar-pos cache
    pe = jnp.zeros((1, cfg.n_prefix_embeds, cfg.d_model))
    pe = pe.at[0, 0, :env.obs_dim].set(obs_v)
    toks = jnp.zeros((1, 1), jnp.int32)                  # BOS anchor
    W = cfg.n_prefix_embeds + 1 + max_new
    logits, cache = prefill(cfg, params, toks, pe, cache_len=W)
    tok = jnp.argmax(logits[0, -1])
    ref = [int(tok)]
    for _ in range(max_new - 1):
        logits, cache = decode_step(cfg, params, tok[None], cache)
        tok = jnp.argmax(logits[0, 0])
        ref.append(int(tok))

    eng = DecodeEngine(cfg, params, slots=3, max_new=max_new, max_prompt=4)
    sch = SlotScheduler(eng)
    assert sch.admit(Request(uid=0, max_new=max_new, obs=obs_v)) is None
    (res,) = sch.drain()
    assert res.tokens == ref


def test_single_slot_and_same_tick_refill(policy, params, env):
    """1 slot serializes correctly; equal budgets all finish on the same
    tick, free their slots, and the next admissions reuse them."""
    eng = engine_for_policy(policy, params, slots=3, max_new=4,
                            max_prompt=4)
    sch = SlotScheduler(eng)
    obs_dim = env.obs_dim
    first = [Request(uid=i, max_new=3, obs=np.full(obs_dim, 0.1 * i,
                                                   np.float32))
             for i in range(3)]
    for r in first:
        assert sch.admit(r) is None
    assert not sch.has_free() and sch.busy() == 3
    done = []
    while not done:                      # all three retire on one tick
        done = sch.tick()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert sch.idle() and len(sch.free) == 3
    second = [Request(uid=10 + i, max_new=2,
                      obs=np.full(obs_dim, -0.2 * i, np.float32))
              for i in range(3)]
    for r in second:
        assert sch.admit(r) is None
    got = sch.drain()
    assert sorted(r.uid for r in got) == [10, 11, 12]
    assert all(len(r.tokens) == 2 for r in got)


def test_budget_one_completes_at_prefill(policy, params, env):
    """max_new=1 never occupies a slot: prefill already made the token."""
    eng = engine_for_policy(policy, params, slots=1, max_new=4,
                            max_prompt=4)
    sch = SlotScheduler(eng)
    res = sch.admit(Request(uid=5, max_new=1,
                            obs=np.zeros(env.obs_dim, np.float32)))
    assert res is not None and len(res.tokens) == 1
    assert sch.idle() and sch.has_free()


def test_token_budgets_respected(policy, params, env):
    traffic = make_traffic(6, seed=11, rate_rps=500.0, max_new=8,
                           obs_dim=env.obs_dim)
    eng = engine_for_policy(policy, params, slots=2, max_new=8,
                            max_prompt=4)
    report = PolicyServer(eng, warmup=False).run_offline(traffic)
    budgets = {r.uid: r.max_new for r in traffic}
    for r in report.results:
        assert len(r.tokens) == budgets[r.uid]


def test_no_recompile_per_request(policy, params, env):
    """After warmup, an entire request stream (mixed budgets, mixed
    arrival patterns, slot churn) triggers zero XLA compiles."""
    from repro.analysis.retrace import CompileLog
    eng = engine_for_policy(policy, params, slots=2, max_new=6,
                            max_prompt=4)
    server = PolicyServer(eng, warmup=True)     # compiles everything here
    traffic = make_traffic(9, seed=5, rate_rps=500.0, max_new=6,
                           obs_dim=env.obs_dim)
    with CompileLog() as log:
        report = server.run_offline(traffic)
    assert len(report.results) == 9
    assert log.compiles() == [], log.compiles()


def test_realtime_matches_offline(policy, params, env):
    """The threaded realtime loop returns the same tokens per uid as the
    offline loop — scheduling differs, numerics must not."""
    traffic = make_traffic(8, seed=9, rate_rps=2000.0, max_new=6,
                           obs_dim=env.obs_dim)
    offline = _tokens_by_uid(policy, params, traffic, slots=2)
    eng = engine_for_policy(policy, params, slots=2, max_new=6,
                            max_prompt=4)
    report = PolicyServer(eng, warmup=False).run(traffic)
    assert {r.uid: r.tokens for r in report.results} == offline
    assert all(r.latency_s >= 0 for r in report.results)


def test_servefns_dataclass_and_shim():
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.distributed.serving import make_serve_fns

    cfg = reduced(get_config("llama3_2_1b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fns = make_serve_fns(cfg, mesh, batch=2, seq_len=16, key=key)
    assert callable(fns.prefill) and callable(fns.decode)
    assert set(fns.shardings) == {"params", "cache", "batch_spec"}
    assert fns.specs["params_shape"] is fns.params_shape
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        pf, dc, specs = fns              # legacy tuple unpacking
    assert pf is fns.prefill and dc is fns.decode
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)


def test_public_api_surface():
    import repro
    for name in ("Experiment", "ScenarioGrid", "run_grid", "register",
                 "resolve", "Spec", "save", "restore", "serve",
                 "get_config", "reduced", "make_env"):
        assert name in repro.__all__, name
        assert getattr(repro, name) is not None
    import repro.serving
    assert repro.serve is repro.serving.serve
    assert repro.obs.progress is not None
    with pytest.raises(AttributeError):
        repro.not_a_real_name


def test_deep_import_lint_rule(tmp_path):
    import ast
    from repro.analysis.lint import DeepImport, FileCtx, LintConfig

    cfg = LintConfig(root=tmp_path)
    rule = DeepImport()

    def findings(src, rel="examples/demo.py"):
        ctx = FileCtx(rel, ast.parse(src), src.splitlines())
        assert rule.wants(ctx, cfg) == rel.startswith("examples/")
        return list(rule.visit(ctx, cfg)) if rule.wants(ctx, cfg) else []

    hit = findings("from repro.core.engine import Experiment\n")
    assert len(hit) == 1 and "Experiment" in hit[0].message
    assert not findings("from repro import Experiment\n")
    assert not findings("from repro.models.model import decode_step\n")
    assert not findings("# analysis: deep-import\n"
                        "from repro.core.engine import Experiment\n")
    # src/ files may deep-import freely — the rule is examples-scoped
    assert not findings("from repro.core.engine import Experiment\n",
                        rel="src/repro/launch/x.py")


def test_serving_obs_telemetry(policy, params, env):
    """Per-request records and gauges only under obs.enabled()."""
    from repro import obs
    traffic = make_traffic(4, seed=2, rate_rps=500.0, max_new=4,
                           obs_dim=env.obs_dim)
    eng = engine_for_policy(policy, params, slots=2, max_new=4,
                            max_prompt=4)
    with obs.capture() as sink:
        PolicyServer(eng, warmup=False).run_offline(traffic)
    reqs = [r for r in sink.records if r.get("stream") == "serve.request"]
    gauges = [r for r in sink.records if r.get("stream") == "serve.gauge"]
    assert len(reqs) == 4
    assert all({"uid", "latency_ms", "ttft_ms", "tokens"} <= set(r)
               for r in reqs)
    assert gauges and all(0 <= g["slots_busy"] <= 2 for g in gauges)
    # off by default: the same run emits nothing
    eng2 = engine_for_policy(policy, params, slots=2, max_new=4,
                             max_prompt=4)
    from repro.obs.sinks import MemorySink
    sink2 = obs.get_recorder().add_sink(MemorySink())
    try:
        PolicyServer(eng2, warmup=False).run_offline(traffic)
    finally:
        obs.get_recorder().remove_sink(sink2)
    assert not [r for r in sink2.records
                if r.get("stream", "").startswith("serve.")
                and r["stream"] != "serve.done"]
