"""Prefill→decode equals full forward, for every cache type: GQA KV ring,
MLA latent, recurrent SSM/xLSTM states, sliding-window rings, MLA
absorbed-vs-naive decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.attention import mla_decode
from repro.models.model import decode_step, forward, init_params, prefill

KEY = jax.random.PRNGKey(1)

ARCHS = ["llama3_2_1b", "qwen2_7b", "minicpm3_4b", "hymba_1_5b",
         "xlstm_350m", "musicgen_medium"]


def _no_drop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    return cfg


@pytest.mark.parametrize("arch", ARCHS + ["grok_1_314b",
                                          "deepseek_v2_lite_16b"])
def test_prefill_decode_matches_forward(arch):
    cfg = _no_drop(reduced(get_config(arch)))
    p = init_params(cfg, KEY)
    B, S, n_dec = 2, 12, 4
    toks = jax.random.randint(KEY, (B, S + n_dec), 0, cfg.vocab_size)
    full, _, _ = forward(cfg, p, toks)
    logits, cache = prefill(cfg, p, toks[:, :S], cache_len=S + n_dec)
    errs = [float(jnp.max(jnp.abs(logits[:, -1] - full[:, S - 1])))]
    for i in range(n_dec):
        lg, cache = decode_step(cfg, p, toks[:, S + i], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, S + i]))))
    assert max(errs) < 1e-3, (arch, errs)


@pytest.mark.parametrize("arch", ["llama3_2_1b", "minicpm3_4b",
                                  "hymba_1_5b"])
def test_sliding_window_ring_cache(arch):
    """A ring cache of size W must equal a window-W masked full forward —
    this is the long_500k serving mode."""
    cfg = reduced(get_config(arch))
    p = init_params(cfg, KEY)
    B, S, n_dec, W = 2, 12, 6, 8
    toks = jax.random.randint(KEY, (B, S + n_dec), 0, cfg.vocab_size)
    fullw, _, _ = forward(cfg, p, toks, window=W)
    lg, cache = prefill(cfg, p, toks[:, :S], cache_len=W, window=W)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - fullw[:, S - 1])))]
    for i in range(n_dec):
        lgd, cache = decode_step(cfg, p, toks[:, S + i], cache)
        errs.append(float(jnp.max(jnp.abs(lgd[:, 0] - fullw[:, S + i]))))
    assert max(errs) < 1e-3, (arch, errs)


def test_mla_absorbed_equals_naive_decode():
    """DeepSeek weight-absorption identity (§Perf optimization)."""
    from repro.models.attention import init_mla
    cfg = reduced(get_config("minicpm3_4b"))
    p = init_mla(KEY, cfg, jnp.float32)
    B, W = 2, 8
    x = jax.random.normal(KEY, (B, 1, cfg.d_model))
    cache = {"c": jax.random.normal(KEY, (B, W, cfg.mla.kv_lora_rank)),
             "k_rope": jax.random.normal(
                 KEY, (B, W, cfg.mla.qk_rope_head_dim))}
    pos = jnp.asarray(5, jnp.int32)
    slots = jnp.arange(W).at[pos % W].set(pos)
    o1, c1 = mla_decode(p, cfg, x, pos, cache, slots, absorb=True)
    o2, c2 = mla_decode(p, cfg, x, pos, cache, slots, absorb=False)
    np.testing.assert_allclose(o1, o2, atol=1e-4)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_prefill_longer_than_ring():
    """Prompt longer than the ring: cache must keep exactly the last W."""
    cfg = reduced(get_config("llama3_2_1b"))
    p = init_params(cfg, KEY)
    B, S, W, n_dec = 1, 20, 8, 3
    toks = jax.random.randint(KEY, (B, S + n_dec), 0, cfg.vocab_size)
    fullw, _, _ = forward(cfg, p, toks, window=W)
    lg, cache = prefill(cfg, p, toks[:, :S], cache_len=W, window=W)
    assert float(jnp.max(jnp.abs(lg[:, -1] - fullw[:, S - 1]))) < 1e-3
    for i in range(n_dec):
        lgd, cache = decode_step(cfg, p, toks[:, S + i], cache)
        assert float(jnp.max(jnp.abs(lgd[:, 0] - fullw[:, S + i]))) < 1e-3
