"""Telemetry-overhead benchmark (DESIGN.md §8).

Measures the fused DecByzPG loop warm us/iteration with telemetry **off**
(the default — must be the exact seed program) and **on** (in-loop taps
streaming to a JSONL sink), at the smoke and fig1 sweep points. Rows land
in ``benchmarks/BENCH_obs.json``:

* ``fused_off`` — the gated baseline: ``check_regress.py`` asserts the
  off path stays within tolerance of the committed numbers, i.e. adding
  the telemetry layer cost the default path nothing;
* ``fused_on``  — the same loop compiled with ``telemetry=True``
  (ungated: callback cost is host-scheduler noise at smoke sizes, and
  the on path is opt-in by design); carries ``overhead_vs_off``.

The doc declares its row identity via the generic ``key_fields``
fallback in ``check_regress.py`` instead of a hard-coded schema branch.

  PYTHONPATH=src python -m benchmarks.bench_obs [--smoke]

``--smoke`` runs only the smoke point and doubles as the CI telemetry
artifact run: the on-path JSONL stream is written to the untracked
``benchmarks/TELEMETRY_smoke.jsonl`` and the host-span Chrome trace to
``benchmarks/TRACE_smoke.json`` (both uploaded by CI, loadable in
Perfetto / chrome://tracing).
"""
import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax

N_REP = 3
HERE = os.path.dirname(__file__)

# (env_spec, T, base config kwargs); the first entry is the smoke point.
# sign_flip keeps every Byzantine message adversarial on every round, so
# the rejected-mask stream in the telemetry artifacts is non-trivial.
SIZES = (
    ("cartpole(horizon=20)", 5,
     dict(K=3, n_byz=1, attack="sign_flip", aggregator="krum", N=4, B=2,
          kappa=2, hidden=(8,))),
    ("cartpole(horizon=100)", 10,
     dict(K=13, n_byz=3, attack="sign_flip", aggregator="krum", N=20, B=4,
          kappa=4, hidden=(16, 16))),
)


def _warm_us_per_iter(run, env, cfg, T) -> float:
    run(env, cfg, T)                         # compile + warm-up
    t0 = time.perf_counter()
    for _ in range(N_REP):
        run(env, cfg, T)
    return (time.perf_counter() - t0) * 1e6 / (N_REP * T)


def measure(env_spec: str, T: int, base: dict, jsonl_path: str,
            trace_path=None) -> list:
    from repro import obs
    from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
    from repro.rl.envs import make_env

    env = make_env(env_spec)
    cfg = DecByzPGConfig(**base, seed=0)
    off_us = _warm_us_per_iter(run_decbyzpg, env, cfg, T)

    cfg_on = dataclasses.replace(cfg, telemetry=True)
    obs.get_tracer().clear()
    with obs.telemetry(obs.JsonlSink(jsonl_path)):
        with obs.host_span("bench_obs.fused_on", env=env_spec, T=T):
            on_us = _warm_us_per_iter(run_decbyzpg, env, cfg_on, T)
    if trace_path is not None:
        obs.write_trace(trace_path)

    shared = {"env": env_spec, "K": base["K"], "T": T}
    obs.progress(f"bench_obs {env_spec} K={base['K']} T={T}: "
                 f"off={off_us:.1f}us/iter on={on_us:.1f}us/iter "
                 f"({on_us / off_us:.2f}x)")
    return [
        {"name": "fused_off", "us_per_call": off_us, **shared},
        # wall_us_per_iter (not us_per_call) so the on path never gates
        {"name": "fused_on", "wall_us_per_iter": on_us,
         "overhead_vs_off": on_us / off_us, **shared},
    ]


def run(smoke: bool = False) -> dict:
    from repro import obs
    rows = []
    sizes = SIZES[:1] if smoke else SIZES
    with tempfile.TemporaryDirectory() as tmp:
        for i, (env_spec, T, base) in enumerate(sizes):
            if smoke:
                jsonl = os.path.join(HERE, "TELEMETRY_smoke.jsonl")
                trace = os.path.join(HERE, "TRACE_smoke.json")
            else:
                jsonl = os.path.join(tmp, f"metrics_{i}.jsonl")
                trace = None
            rows += measure(env_spec, T, base, jsonl, trace)
    doc = {"bench": "obs", "backend": jax.default_backend(),
           "smoke": smoke,
           # generic check_regress row identity (no hard-coded branch)
           "key_fields": ["name", "env", "K", "T"],
           "rows": rows}
    name = "BENCH_obs_smoke.json" if smoke else "BENCH_obs.json"
    path = os.path.join(HERE, name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smoke point only); also "
                         "writes the TELEMETRY_smoke.jsonl / "
                         "TRACE_smoke.json CI artifacts")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
