"""Topology benchmark: agreement wall-clock and honest-diameter
contraction vs. gossip-graph density.

Runs ``avg_agree`` (jitted, per-receiver equivocation attack active) over
a ladder of topologies at fixed (K, d, kappa) and records per-round
wall-clock plus the observed Δ₂ contraction factor, alongside each
graph's static diagnostics (density, max degree, spectral gap, Fiedler
value). Results go to ``benchmarks/BENCH_topology.json`` so the
agreement hot path's perf trajectory stays machine-readable across PRs.

  PYTHONPATH=src python -m benchmarks.bench_topology [--smoke]

``--smoke`` shrinks (K, d, repeats) to a seconds-scale run for CI — same
code path, same JSON schema (flagged ``"smoke": true``).
"""
import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

TOPOLOGIES = ("complete", "ring(k=2)", "ring(k=4)", "torus",
              "small_world(k=4, beta=0.3)", "erdos_renyi(p=0.4, seed=0)",
              "star")


def run(K: int = 16, d: int = 20_000, kappa: int = 4, n_byz: int = 3,
        repeats: int = 5, smoke: bool = False) -> dict:
    from repro.core import attacks as attacks_lib
    from repro.core.agreement import avg_agree, honest_diameter
    from repro.topology import resolve_topology

    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (K, d))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    hmask = ~byz_mask
    attack = attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=50.0), K)
    d0 = float(honest_diameter(theta, hmask))

    rows = []
    print("name,us_per_round,derived", flush=True)
    for spec in TOPOLOGIES:
        topo = resolve_topology(spec, K)
        fn = jax.jit(lambda th, k, t=topo: avg_agree(
            th, kappa, n_byz, byz_mask, "gda", attack, k, topology=t))
        out = jax.block_until_ready(fn(theta, key))      # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(theta, key)
        jax.block_until_ready(out)
        us_round = (time.perf_counter() - t0) / repeats / kappa * 1e6
        dk = float(honest_diameter(out, hmask))
        contraction = dk / d0 if d0 > 0 else 0.0
        rows.append({
            "topology": topo.name,
            "density": topo.density,
            "deg_max": topo.deg_max,
            "min_in_degree": topo.min_in_degree,
            "spectral_gap": topo.spectral_gap,
            "algebraic_connectivity": topo.algebraic_connectivity,
            "tolerates_n_byz": topo.tolerates(n_byz),
            "us_per_round": us_round,
            "diameter_contraction": contraction,
        })
        print(f"topology_{topo.spec.name},{us_round:.1f},"
              f"density={topo.density:.2f};contraction={contraction:.3f};"
              f"deg_max={topo.deg_max}", flush=True)

    doc = {"bench": "topology", "backend": jax.default_backend(),
           "smoke": smoke, "K": K, "d": d, "kappa": kappa, "n_byz": n_byz,
           "method": "gda", "attack": "per_receiver large_noise(sigma=50)",
           "initial_diameter": d0, "rows": rows}
    # smoke runs get their own file so a CI-sized run can't silently
    # replace the tracked full-ladder baseline
    name = "BENCH_topology_smoke.json" if smoke else "BENCH_topology.json"
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {path}", flush=True)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (small K/d, fewer repeats)")
    args = ap.parse_args()
    if args.smoke:
        run(K=8, d=512, kappa=3, n_byz=1, repeats=2, smoke=True)
    else:
        run()


if __name__ == "__main__":
    main()
