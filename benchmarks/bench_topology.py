"""Topology benchmark: agreement wall-clock and honest-diameter
contraction vs. gossip-graph density.

Runs ``avg_agree`` (jitted, per-receiver equivocation attack active) over
a ladder of topologies at each (K, d, kappa) ladder point and records
per-round wall-clock (min over repeats — scheduler noise only adds time)
plus the observed Δ₂ contraction factor, alongside each graph's static
diagnostics (density, max degree, spectral gap, Fiedler value). Results
go to ``benchmarks/BENCH_topology.json`` so the agreement hot path's
perf trajectory stays machine-readable across PRs.

  PYTHONPATH=src python -m benchmarks.bench_topology [--smoke]

``--smoke`` runs only the smallest ladder point with fewer repeats — the
same code path and JSON schema (flagged ``"smoke": true``), written to
the untracked ``BENCH_topology_smoke.json``. Every row carries its own
(K, d, kappa, n_byz), and the full baseline includes the smoke-sized
point, so ``check_regress.py`` can match smoke rows against the
committed baseline by key.
"""
import argparse
import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.timing import min_time_s

from repro import obs

TOPOLOGIES = ("complete", "ring(k=2)", "ring(k=4)", "torus",
              "small_world(k=4, beta=0.3)", "erdos_renyi(p=0.4, seed=0)",
              "star")

# (K, d, kappa, n_byz) ladder; the first entry is the smoke point
SIZES = ((8, 512, 3, 1), (16, 20_000, 4, 3))


def measure(K: int, d: int, kappa: int, n_byz: int, repeats: int) -> list:
    from repro.core import attacks as attacks_lib
    from repro.core.agreement import avg_agree, honest_diameter
    from repro.topology import resolve_topology

    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (K, d))
    byz_mask = jnp.asarray(np.arange(K) < n_byz)
    hmask = ~byz_mask
    attack = attacks_lib.per_receiver(
        attacks_lib.get_attack("large_noise", sigma=50.0), K)
    d0 = float(honest_diameter(theta, hmask))

    rows = []
    for spec in TOPOLOGIES:
        topo = resolve_topology(spec, K)
        fn = jax.jit(lambda th, k, t=topo: avg_agree(
            th, kappa, n_byz, byz_mask, "gda", attack, k, topology=t))
        us_round = min_time_s(fn, theta, key, repeats=repeats) / kappa * 1e6
        out = fn(theta, key)
        dk = float(honest_diameter(out, hmask))
        contraction = dk / d0 if d0 > 0 else 0.0
        rows.append({
            "topology": topo.name,
            "K": K, "d": d, "kappa": kappa, "n_byz": n_byz,
            "density": topo.density,
            "deg_max": topo.deg_max,
            "min_in_degree": topo.min_in_degree,
            "spectral_gap": topo.spectral_gap,
            "algebraic_connectivity": topo.algebraic_connectivity,
            "tolerates_n_byz": topo.tolerates(n_byz),
            "initial_diameter": d0,
            "us_per_round": us_round,
            "diameter_contraction": contraction,
        })
        obs.progress(f"topology_{topo.spec.name},{us_round:.1f},"
                     f"K={K};d={d};density={topo.density:.2f};"
                     f"contraction={contraction:.3f};deg_max={topo.deg_max}")
    return rows


def run(smoke: bool = False) -> dict:
    obs.progress("name,us_per_round,derived")
    if smoke:
        rows = measure(*SIZES[0], repeats=10)
    else:
        rows = []
        for size in SIZES:
            rows += measure(*size, repeats=10)
    doc = {"bench": "topology", "backend": jax.default_backend(),
           "smoke": smoke, "method": "gda",
           "attack": "large_noise(sigma=50)", "per_receiver": True,
           "rows": rows}
    # smoke runs get their own file so a CI-sized run can't silently
    # replace the tracked full-ladder baseline
    name = "BENCH_topology_smoke.json" if smoke else "BENCH_topology.json"
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smallest ladder point only)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
