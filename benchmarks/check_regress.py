"""CI perf-regression gate over the BENCH_*.json files.

Compares freshly-written smoke benchmark files against the committed
full-run baselines and fails (exit 1) when any matched entry is more than
``--tol``× slower than its baseline. Entries whose key is absent from the
baseline are skipped (so a smoke run at CI size only gates the ladder
points the baseline actually contains), as are entries whose baseline
time is below ``--min-us`` (micro-entries drown in scheduler noise).

  python -m benchmarks.check_regress \\
      --pair benchmarks/BENCH_kernels_smoke.json:benchmarks/BENCH_kernels.json \\
      --pair benchmarks/BENCH_topology_smoke.json:benchmarks/BENCH_topology.json

Baselines are committed from a developer run of the full benchmarks;
absolute wall-clock differs across machines, which is why the default
tolerance is a generous 2× — the gate exists to catch order-of-magnitude
perf bugs (an accidental de-jit, an interpret-mode fallback, a quadratic
blowup), not 10% drift.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional, Tuple


def row_key(doc: dict, row: dict) -> Optional[Tuple]:
    """Identity of one benchmark entry, comparable across runs. Includes
    every size parameter so differently-sized runs never alias.

    Known schemas are keyed explicitly below. Any other doc may declare
    its own row identity via a top-level ``"key_fields": [...]`` list —
    each named field is read from the row (falling back to a doc-level
    value), so new benchmarks gate without touching this file."""
    bench = doc.get("bench")
    if bench == "kernels":
        return (bench, row["kernel"], row["backend"],
                row["K"], row["P"], row["D"])
    if bench == "topology":
        # sizes are per-row since PR 4; fall back to the doc-level fields
        # older BENCH_topology.json files carried
        get = lambda k: row.get(k, doc.get(k))
        return (bench, row["topology"], get("K"), get("d"), get("kappa"),
                get("n_byz"))
    if bench == "engine":
        # sweep rows carry (L, S); single-config rows leave them None
        return (bench, row["name"], row.get("env"), row.get("K"),
                row.get("T"), row.get("L"), row.get("S"))
    if bench == "aggregation":
        # only us_per_call gates; the *_bytes fields are informational
        return (bench, row["aggregator"], row["backend"],
                row["K"], row["D"])
    key_fields = doc.get("key_fields")
    if key_fields:
        return (bench, *(row.get(f, doc.get(f)) for f in key_fields))
    return None                       # unknown schema: never gates


def row_us(row: dict) -> Optional[float]:
    for k in ("us_per_call", "us_per_round"):
        if k in row:
            return float(row[k])
    return None


def load_rows(path: str) -> Tuple[dict, dict]:
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("rows", ()):
        key, us = row_key(doc, row), row_us(row)
        if key is not None and us is not None:
            rows[key] = us
    return doc, rows


def check_pair(current: str, baseline: str, tol: float,
               min_us: float) -> list:
    """Returns the list of regressions; prints per-pair status."""
    if not os.path.exists(current):
        print(f"check_regress: {current} not found — skipping pair")
        return []
    if not os.path.exists(baseline):
        print(f"check_regress: baseline {baseline} not found — "
              f"skipping pair")
        return []
    _, cur = load_rows(current)
    _, base = load_rows(baseline)
    regressions, matched, skipped = [], 0, 0
    for key, us in sorted(cur.items()):
        if key not in base:
            skipped += 1
            continue
        if base[key] < min_us:
            skipped += 1
            continue
        matched += 1
        ratio = us / base[key]
        if ratio > tol:
            regressions.append((key, base[key], us, ratio))
    print(f"check_regress: {current} vs {baseline}: {matched} gated, "
          f"{skipped} skipped (absent/below {min_us}us), "
          f"{len(regressions)} regressed")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", default=[],
                    metavar="CURRENT:BASELINE",
                    help="colon-separated current:baseline json paths "
                         "(repeatable)")
    ap.add_argument("--tol", type=float, default=2.0,
                    help="fail when current > tol * baseline (default 2.0)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="ignore entries whose baseline is faster than "
                         "this (default 200us; sub-dispatch-scale entries "
                         "flap on shared runners)")
    args = ap.parse_args(argv)
    if not args.pair:
        ap.error("at least one --pair is required")
    regressions = []
    for pair in args.pair:
        current, _, baseline = pair.partition(":")
        if not baseline:
            ap.error(f"--pair needs CURRENT:BASELINE, got {pair!r}")
        regressions += check_pair(current, baseline, args.tol, args.min_us)
    for key, base_us, cur_us, ratio in regressions:
        print(f"REGRESSION {'/'.join(map(str, key))}: "
              f"{base_us:.1f}us -> {cur_us:.1f}us ({ratio:.2f}x > "
              f"{args.tol:.2f}x)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
