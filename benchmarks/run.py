"""Benchmark harness (deliverable (d)) — one function per paper table/figure
plus system micro-benchmarks and the roofline report.

Prints ``name,us_per_call,derived`` CSV rows. Figure analogues run
shortened-but-faithful configurations (full curves: examples/).

  PYTHONPATH=src python -m benchmarks.run [--only fig1_speedup,...]
"""
import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs


def _timeit(fn, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _row(name, us, derived=""):
    obs.progress(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# Figure 1/4: speed-up with federation size K (DecByzPG, alpha = 0)
# ---------------------------------------------------------------------------

SEEDS = (0, 1, 2)
T_FIG = 15
BENCH_ENV = "cartpole(horizon=100)"


def _experiment_rows(axes, T, algo, name_fn, override=None, **base):
    """Run each axis combination as a one-scenario Experiment and emit one
    CSV row; us_per_call is wall time per scan iteration for the whole
    vmapped seed batch (compile cached across calls, warmed first)."""
    import itertools

    from repro.core.engine import Experiment
    for combo in itertools.product(*axes.values()):
        exp = Experiment(algo=algo, env=BENCH_ENV, T=T, seeds=SEEDS,
                         axes={k: (v,) for k, v in zip(axes, combo)},
                         override=override, **base)
        exp.run()                                   # warm the loop cache
        t0 = time.perf_counter()
        res = exp.run(force=True)
        us = (time.perf_counter() - t0) * 1e6 / T
        (scn, out), = res.items()
        _row(name_fn(scn), us,
             f"seeds={len(SEEDS)};"
             f"final_return={out['final_return_mean']:.1f}"
             f"±{out['final_return_ci95']:.1f};"
             f"samples_per_agent={int(out['samples'][:, -1].mean())}")


def fig1_speedup():
    import dataclasses as dc
    _experiment_rows({"K": (1, 5, 13)}, T_FIG, "decbyzpg",
                     lambda s: f"fig1_decbyzpg_K{s.K}",
                     N=20, B=4, eta=2e-2,
                     override=lambda c: dc.replace(
                         c, kappa=4 if c.K > 1 else 0))


# ---------------------------------------------------------------------------
# Figures 2/3: resilience under attack (DecByzPG vs naive Dec-PAGE-PG)
# ---------------------------------------------------------------------------

def fig2_attacks():
    import dataclasses as dc

    # paper-exact: 3 of 13 agents Byzantine (the largest count tolerated by
    # Assumption 1); aggregator axis "mean" is the naive Dec-PAGE-PG
    # baseline (no agreement), "rfa" is DecByzPG.
    names = {"rfa": "decbyzpg", "mean": "dec_page_pg"}
    _experiment_rows(
        {"attack": ("random_action", "large_noise", "avg_zero"),
         "aggregator": ("rfa", "mean")},
        T_FIG, "decbyzpg",
        lambda s: f"fig2_{s.attack}_{names[s.aggregator]}",
        K=13, n_byz=3, N=20, B=4, eta=2e-2,
        override=lambda c: dc.replace(
            c, kappa=0 if c.aggregator.name == "mean" else 4))


# ---------------------------------------------------------------------------
# Figure 5/6 analogue: centralized ByzPG resilience
# ---------------------------------------------------------------------------

def fig5_byzpg_attacks():
    names = {"rfa": "byzpg", "mean": "fed_page_pg"}
    _experiment_rows(
        {"attack": ("large_noise", "avg_zero"),
         "aggregator": ("rfa", "mean")},
        T_FIG, "byzpg",
        lambda s: f"fig5_{s.attack}_{names[s.aggregator]}",
        K=13, n_byz=3, N=20, B=4, eta=2e-2)


# ---------------------------------------------------------------------------
# Micro: fused scan engine vs legacy per-step dispatch loop
# ---------------------------------------------------------------------------

def bench_engine():
    """Fused-scan vs legacy dispatch, plus the lane-batched sweep vs the
    per-scenario loop; writes ``benchmarks/BENCH_engine.json`` (full
    ladder lives in ``benchmarks/bench_engine.py``, which also has a
    ``--smoke`` CLI for the CI-sized sweep point)."""
    from benchmarks.bench_engine import run as run_engine
    run_engine()


# ---------------------------------------------------------------------------
# Micro: robust aggregators at LLM-gradient scale
# ---------------------------------------------------------------------------

def bench_topology():
    """Agreement wall-clock + Δ₂ contraction vs gossip-graph density;
    writes ``benchmarks/BENCH_topology.json`` (full ladder lives in
    ``benchmarks/bench_topology.py``, which also has a ``--smoke`` CLI)."""
    from benchmarks.bench_topology import run as run_topology
    run_topology()


def bench_aggregators():
    from repro.core.aggregators import get_aggregator
    K, d, n_byz = 13, 200_000, 3
    x = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    key = jax.random.PRNGKey(1)
    for name in ("mean", "krum", "rfa", "cwmed", "trimmed_mean"):
        f = jax.jit(get_aggregator(name, K, n_byz))
        us = _timeit(lambda: f(x, key))
        _row(f"agg_{name}_K{K}_d{d}", us, f"bytes={x.nbytes}")


def bench_agreement():
    from repro.core.agreement import avg_agree
    K, d = 13, 50_000
    theta = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    for method in ("gda", "mda"):
        f = jax.jit(lambda t, m=method: avg_agree(t, kappa=4, n_byz=3,
                                                  method=m))
        us = _timeit(lambda: f(theta), n=3)
        _row(f"agree_{method}_k4_K{K}_d{d}", us)


def bench_kernels():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.pairwise_dist import ref as pd_ref
    from repro.kernels.trimmed_mean import ref as tm_ref
    K, d = 16, 1_000_000
    x = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    us = _timeit(lambda: jax.jit(pd_ref.pairwise_sq_dists)(x), n=5)
    _row(f"kernel_pairwise_ref_K{K}_d{d}", us)
    us = _timeit(lambda: jax.jit(tm_ref.trimmed_mean,
                                 static_argnums=1)(x, 2), n=5)
    _row(f"kernel_trimmed_ref_K{K}_d{d}", us)
    B, S, H, hd = 1, 1024, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    us = _timeit(lambda: flash_attention(q, k, v, use_pallas=False), n=5)
    _row(f"kernel_flash_ref_S{S}", us,
         f"gflops={4*B*H*S*S*hd/1e9:.1f}")


def bench_fed_step():
    from repro.configs.base import get_config, reduced
    from repro.distributed.fed_trainer import (FedConfig, fed_train_step,
                                               init_fed_state)
    cfg = reduced(get_config("llama3_2_1b"))
    fed = FedConfig(aggregator="rfa", kappa=4, n_byz=1,
                    attack="large_noise")
    K = 8
    key = jax.random.PRNGKey(0)
    state = init_fed_state(cfg, fed, K, key)
    batch = {"tokens": jax.random.randint(key, (K, 2, 64), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(key, (K, 2, 64), 0,
                                          cfg.vocab_size)}
    mask = jnp.asarray(np.arange(K) < 1)
    step = jax.jit(lambda s, b, m, k: fed_train_step(
        cfg, fed, s, b, m, k, large=True))
    state, _ = step(state, batch, mask, key)       # compile

    def run():
        s2, m = step(state, batch, mask, key)
        return m["loss"]

    us = _timeit(run, n=3, warmup=1)
    _row("fed_step_llama_reduced_K8", us)


# ---------------------------------------------------------------------------
# Roofline report (from the dry-run artifacts) — EXPERIMENTS.md §Roofline
# ---------------------------------------------------------------------------

def bench_roofline():
    base = os.path.join(os.path.dirname(__file__), "..", "results")
    path = None
    for name in ("optimized_single_pod.json", "baseline_v2.json",
                 "dryrun_single_pod.json"):
        cand = os.path.join(base, name)
        if os.path.exists(cand):
            path = cand
            break
    if path is None:
        _row("roofline", 0.0, "skipped=run repro.launch.dryrun --all first")
        return
    for r in json.load(open(path)):
        if not r.get("ok"):
            _row(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                 f"FAILED={r.get('error', '')[:60]}")
            continue
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        _row(f"roofline_{r['arch']}_{r['shape']}", dom * 1e6,
             f"bottleneck={t['bottleneck']};compute_s={t['compute_s']:.2e};"
             f"memory_s={t['memory_s']:.2e};"
             f"collective_s={t['collective_s']:.2e};"
             f"useful_ratio={t['useful_ratio']}")


def ablation_kappa_aggregator():
    """Beyond-paper ablation: agreement depth (kappa) x aggregator under
    AvgZero — Theorem 2's O(2^-kappa) bias term, observed as final return
    and honest parameter diameter."""
    from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
    from repro.rl.envs import make_cartpole
    env = make_cartpole(horizon=100)
    for agg in ("rfa", "trimmed_mean"):
        for kappa in (0, 2, 5):
            cfg = DecByzPGConfig(K=13, n_byz=3, attack="avg_zero",
                                 aggregator=agg, kappa=kappa, N=10, B=2,
                                 eta=2e-2, seed=0)
            t0 = time.perf_counter()
            out = run_decbyzpg(env, cfg, T=10)
            us = (time.perf_counter() - t0) * 1e6 / 10
            _row(f"ablate_{agg}_kappa{kappa}", us,
                 f"final_return={np.mean(out['returns'][-3:]):.1f};"
                 f"diam={out['diameter'][-1]:.2e}")


ALL = {
    "fig1_speedup": fig1_speedup,
    "fig2_attacks": fig2_attacks,
    "fig5_byzpg_attacks": fig5_byzpg_attacks,
    "bench_engine": bench_engine,
    "bench_aggregators": bench_aggregators,
    "bench_agreement": bench_agreement,
    "bench_topology": bench_topology,
    "bench_kernels": bench_kernels,
    "bench_fed_step": bench_fed_step,
    "ablation_kappa_aggregator": ablation_kappa_aggregator,
    "bench_roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    obs.progress("name,us_per_call,derived")
    for n in names:
        ALL[n]()


if __name__ == "__main__":
    main()
