"""Shared benchmark timing: min-of-repeats wall clock.

Scheduler noise only ever adds time, so the minimum over repeats is the
stable estimator the perf-regression gate needs (mean-based timing flaps
on shared runners). ``benchmarks/run.py`` keeps its mean-based `_timeit`
for the paper-figure rows, where throughput under load is the quantity
of interest.
"""
import time

import jax


def min_time_s(fn, *args, repeats: int) -> float:
    """Best-of-``repeats`` seconds per ``fn(*args)`` call, after one
    untimed compile/warmup call."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best
