"""Serving benchmark: continuous-batching decode latency + throughput.

Serves a reproducible simulated request stream (fixed seed, Poisson
arrivals) against a reduced transformer policy through the
``repro.serving`` engine and reports, per (slots, n_requests, max_new)
point:

* ``decode_tick`` — warm jitted tick wall time (``us_per_call``, gated:
  this is the hot loop; a de-jit or a per-request recompile shows up
  here as an order-of-magnitude jump);
* ``latency_p50`` / ``latency_p99`` — per-request end-to-end latency
  over the offline deterministic replay (``us_per_call``, gated);
* ``throughput`` — aggregate tokens/sec (informational: wall-clock
  throughput of the host loop is scheduler-noise-sensitive at smoke
  sizes, so it never gates).

Rows land in ``benchmarks/BENCH_serving.json`` (full run, committed) /
``BENCH_serving_smoke.json`` (CI artifact); ``check_regress.py`` gates
the smoke rows against the committed baseline via the generic
``key_fields`` identity.

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
import argparse
import json
import os
import time

import jax

N_REP = 5
HERE = os.path.dirname(__file__)

# (slots, n_requests, max_new); first entry is the smoke point
SIZES = (
    (2, 8, 6),
    (4, 32, 16),
    (8, 64, 16),
)

_POLICY = ("transformer(arch='llama3.2-1b', n_layers=2, d_model=64, "
           "n_heads=2)")
_ENV = "cartpole(horizon=32)"


def _build(slots, max_new):
    from repro.core.registry import resolve
    from repro.rl.envs import make_env
    from repro.serving import PolicyServer, engine_for_policy

    env = make_env(_ENV)
    policy = resolve("policy", _POLICY, env=env)
    params = policy.init(jax.random.PRNGKey(0))
    engine = engine_for_policy(policy, params, slots=slots,
                               max_new=max_new, max_prompt=8)
    return env, engine, PolicyServer(engine)    # warmup compiles programs


def measure(slots: int, n_requests: int, max_new: int,
            jsonl_path=None) -> list:
    import contextlib

    from repro import obs
    from repro.serving import make_traffic

    env, engine, server = _build(slots, max_new)

    # warm tick latency on a fully-occupied state
    sched = server.scheduler
    traffic = make_traffic(slots, seed=1, rate_rps=1e6, max_new=max_new,
                           obs_dim=env.obs_dim, jitter_budget=False)
    for req in traffic:
        sched.admit(req)
    sched.tick()                                  # warm
    t0 = time.perf_counter()
    for _ in range(N_REP):
        sched.tick()
    tick_us = (time.perf_counter() - t0) * 1e6 / N_REP
    sched.drain()

    # offline replay for latency percentiles + throughput; the smoke run
    # streams the per-request records + gauges to a JSONL CI artifact
    stream = make_traffic(n_requests, seed=7, rate_rps=200.0,
                          max_new=max_new, obs_dim=env.obs_dim)
    sink = obs.telemetry(obs.JsonlSink(jsonl_path)) if jsonl_path \
        else contextlib.nullcontext()
    with sink:
        report = server.run_offline(stream)
    s = report.summary()

    shared = {"slots": slots, "n_requests": n_requests, "max_new": max_new}
    obs.progress(f"bench_serving slots={slots} n={n_requests} "
                 f"gen={max_new}: tick={tick_us:.0f}us "
                 f"p50={s['latency_p50_ms']}ms p99={s['latency_p99_ms']}ms "
                 f"{s['tokens_per_s']} tok/s")
    return [
        {"name": "decode_tick", "us_per_call": tick_us, **shared},
        {"name": "latency_p50", "us_per_call": s["latency_p50_ms"] * 1e3,
         **shared},
        {"name": "latency_p99", "us_per_call": s["latency_p99_ms"] * 1e3,
         **shared},
        # wall-clock throughput of the host loop: informational only
        {"name": "throughput", "tokens_per_s": s["tokens_per_s"],
         "total_tokens": s["total_tokens"], **shared},
    ]


def run(smoke: bool = False) -> dict:
    from repro import obs
    rows = []
    jsonl = os.path.join(HERE, "TELEMETRY_serving_smoke.jsonl") if smoke \
        else None
    for slots, n_requests, max_new in (SIZES[:1] if smoke else SIZES):
        rows += measure(slots, n_requests, max_new, jsonl_path=jsonl)
    doc = {"bench": "serving", "backend": jax.default_backend(),
           "smoke": smoke,
           "key_fields": ["name", "slots", "n_requests", "max_new"],
           "rows": rows}
    name = "BENCH_serving_smoke.json" if smoke else "BENCH_serving.json"
    path = os.path.join(HERE, name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smoke point only)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
