"""Engine benchmark: fused-scan vs legacy dispatch, and the lane-batched
sweep vs the per-scenario loop (DESIGN.md §2).

Two families of rows land in ``benchmarks/BENCH_engine.json``:

* single-config (full runs only): the historical fused-vs-legacy
  comparison on the fig1 K=13 CartPole config —
  ``legacy_perstep`` / ``fused_cold`` / ``fused_scan``, us per scan
  iteration;
* sweep: an L-point eta sweep × S seeds — ``sweep_perscenario`` (one
  compile + dispatch per scenario, ``lanes=False``) vs ``sweep_lanes``
  (one compiled lane-batched program per static signature). Each row
  carries two timings: ``wall_us_per_scenario``, the cold end-to-end
  sweep wall-clock per scenario *including* compiles (the quantity a
  user sweeping scalars actually waits for, with ``compiles`` and the
  lane row's ``cold_speedup_vs_perscenario``), and ``us_per_call``, the
  warm re-run per scenario (execution + dispatch only). Only
  ``us_per_call`` is gated by ``check_regress.py`` — compile time is
  dominated by XLA/jaxlib version and machine, so gating the cold
  number at 2× would flap on CI runners; the cold columns are the
  recorded perf trajectory, not the gate.

  PYTHONPATH=src python -m benchmarks.bench_engine [--smoke]

``--smoke`` runs only the smallest sweep point with the same schema
(flagged ``"smoke": true``) and writes the untracked
``BENCH_engine_smoke.json``; the full baseline includes the smoke-sized
point, so ``check_regress.py`` matches smoke rows by
(name, env, K, T, L, S) key.
"""
import argparse
import json
import os
import time

import numpy as np

import jax

from repro import obs


ETAS = (1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2)
SEEDS = (0, 1, 2, 3)

# (env_spec, T, base config kwargs); the first entry is the smoke point
SWEEP_SIZES = (
    ("cartpole(horizon=20)", 5,
     dict(K=3, n_byz=1, attack="large_noise(sigma=10)", N=4, B=2, kappa=2,
          hidden=(8,))),
    ("cartpole(horizon=100)", 10,
     dict(K=13, n_byz=3, attack="large_noise(sigma=10)", N=20, B=4,
          kappa=4, hidden=(16, 16))),
)


def _row(name, us, derived=""):
    obs.progress(f"{name},{us:.1f},{derived}")


def measure_sweep(env_spec: str, T: int, base: dict) -> list:
    """Run the eta sweep per-scenario and lane-batched — once from a
    cold compiled-loop cache (compile-inclusive wall-clock, ungated) and
    once warm (gated ``us_per_call``) — and return the two rows."""
    from repro.core import engine
    from repro.core.engine import ScenarioGrid, run_grid
    from repro.rl.envs import make_env

    env = make_env(env_spec)
    grid = ScenarioGrid(seeds=SEEDS, axes={"eta": ETAS})
    L, S = len(ETAS), len(SEEDS)
    rows, cold_walls = [], {}
    for lanes, name in ((False, "sweep_perscenario"),
                        (True, "sweep_lanes")):
        engine.clear_cache()
        t0 = time.perf_counter()
        res = run_grid(env, grid, T, algo="decbyzpg", lanes=lanes, **base)
        cold = time.perf_counter() - t0
        compiles = engine.compile_count()
        t0 = time.perf_counter()
        run_grid(env, grid, T, algo="decbyzpg", lanes=lanes, **base)
        warm = time.perf_counter() - t0
        finals = [res[scn]["final_return_mean"] for scn in res]
        cold_walls[name] = cold
        rows.append({
            "name": name, "env": env_spec, "K": base["K"], "T": T,
            "L": L, "S": S, "us_per_call": warm * 1e6 / L,
            "wall_us_per_scenario": cold * 1e6 / L,
            "compiles": compiles,
        })
        _row(f"engine_{name}_K{base['K']}_T{T}", warm * 1e6 / L,
             f"L={L};S={S};compiles={compiles};"
             f"cold_us_per_scenario={cold * 1e6 / L:.0f};"
             f"final_returns={np.round(finals, 1).tolist()}")
    speedup = (cold_walls["sweep_perscenario"]
               / cold_walls["sweep_lanes"])
    rows[-1]["cold_speedup_vs_perscenario"] = speedup
    _row(f"engine_sweep_speedup_K{base['K']}_T{T}", 0.0,
         f"cold_lanes_vs_perscenario={speedup:.1f}x")
    return rows


def measure_single() -> list:
    """Historical fused-vs-legacy comparison on the fig1 K=13 config."""
    from repro.core.decbyzpg import (DecByzPGConfig, run_decbyzpg,
                                     run_decbyzpg_legacy)
    from repro.rl.envs import make_env

    env_spec = "cartpole(horizon=100)"
    env = make_env(env_spec)
    cfg = DecByzPGConfig(K=13, N=20, B=4, kappa=4, eta=2e-2, seed=0)
    T = 15

    run_decbyzpg_legacy(env, cfg, T)               # process warm-up
    t0 = time.perf_counter()
    out_l = run_decbyzpg_legacy(env, cfg, T)
    legacy_us = (time.perf_counter() - t0) * 1e6 / T

    t0 = time.perf_counter()
    run_decbyzpg(env, cfg, T)                      # cold: includes compile
    fused_cold_us = (time.perf_counter() - t0) * 1e6 / T
    t0 = time.perf_counter()
    out_f = run_decbyzpg(env, cfg, T)
    fused_us = (time.perf_counter() - t0) * 1e6 / T

    match = bool(np.allclose(out_f["returns"], out_l["returns"],
                             atol=1e-4))
    _row("bench_engine_legacy_perstep", legacy_us,
         "per_iter_jit_dispatch+host_sync;rejit_per_call")
    _row("bench_engine_fused_cold", fused_cold_us, "includes_compile")
    _row("bench_engine_fused_scan", fused_us,
         f"speedup_vs_legacy={legacy_us / fused_us:.1f}x;"
         f"trace_matches_legacy={match}")
    # legacy_perstep / fused_cold are compile-dominated (fresh jit per
    # call resp. first compile): recorded as ungated wall_us_per_iter;
    # only the warm fused_scan execution time carries the gated key
    shared = {"env": env_spec, "K": cfg.K, "T": T}
    return [
        {"name": "legacy_perstep", "wall_us_per_iter": legacy_us,
         **shared},
        {"name": "fused_cold", "wall_us_per_iter": fused_cold_us,
         **shared},
        {"name": "fused_scan", "us_per_call": fused_us,
         "speedup_vs_legacy": legacy_us / fused_us,
         "trace_matches_legacy": match, **shared},
    ]


def run(smoke: bool = False) -> dict:
    obs.progress("name,us_per_call,derived")
    rows = []
    sizes = SWEEP_SIZES[:1] if smoke else SWEEP_SIZES
    for env_spec, T, base in sizes:
        rows += measure_sweep(env_spec, T, base)
    if not smoke:
        rows += measure_single()
    doc = {"bench": "engine", "backend": jax.default_backend(),
           "smoke": smoke, "etas": list(ETAS), "seeds": list(SEEDS),
           "rows": rows}
    # smoke runs get their own untracked file so a CI-sized run can't
    # silently replace the tracked full baseline
    name = "BENCH_engine_smoke.json" if smoke else "BENCH_engine.json"
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smallest sweep point only)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
