"""Kernel-suite benchmark: us/call for every dispatched kernel.

Times each kernel in the ``kernel`` registry namespace over a ladder of
(K agents, deg_max P, param-dim D) shapes, on the jnp-oracle backend and
on the Pallas backend (compiled on TPU; the interpreter elsewhere — off
TPU the Pallas numbers measure the interpreter, not the kernel, and are
recorded so interpret-mode blowups in CI stay visible). Results go to
``benchmarks/BENCH_kernels.json``; ``--smoke`` shrinks the ladder to a
seconds-scale run and writes the untracked
``BENCH_kernels_smoke.json`` (same schema, ``"smoke": true``) that
``benchmarks/check_regress.py`` gates CI with.

  PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]
"""
import argparse
import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.timing import min_time_s

from repro import obs

# full ladder; the first entry is the smoke shape, so smoke rows always
# have a matching key in the committed full-ladder baseline
SIZES = ((8, 4, 512), (8, 4, 4096), (16, 8, 4096), (16, 8, 32768))
#: interpret-mode runs above this D are skipped off-TPU (the interpreter
#: is minutes-slow at model scale; the skip is printed, not silent)
INTERPRET_MAX_D = 4096


def _cases(K, P, D, key):
    """kernel name -> (args, kwargs) at this ladder point."""
    x = jax.random.normal(key, (K, D))
    nbr = np.stack([np.sort((np.arange(P) + r) % K) for r in range(K)])
    recv = jax.random.normal(key, (K, P, D))
    # static kernel parameters ride in the kwargs closure (they are jit
    # static args of the Pallas wrappers); only arrays are jit operands
    return {
        "pairwise_dist": ((x,), {}),
        "trimmed_mean": ((x,), {"n_trim": 1}),
        "krum_score": ((x,), {"n_near": max(K - 3, 1)}),
        "rfa": ((x,), {"n_iter": 16}),
        "gossip_reduce": ((x, jnp.asarray(nbr)),
                          {"mode": "trimmed", "n_trim": 1}),
        "neighbor_reduce": ((recv,), {"mode": "median"}),
    }


def run(sizes=SIZES, repeats: int = 20, smoke: bool = False) -> dict:
    from repro.kernels import dispatch

    pallas_backend = "pallas" if dispatch.on_tpu() else "pallas-interpret"
    key = jax.random.PRNGKey(0)
    rows = []
    obs.progress("kernel,backend,K,P,D,us_per_call")
    for K, P, D in sizes:
        for name, (args, kw) in _cases(K, P, D, key).items():
            kernel = dispatch.get_kernel(name)
            for backend in ("jnp", pallas_backend):
                if backend == "pallas-interpret" and D > INTERPRET_MAX_D:
                    obs.progress(f"# skip {name}/{backend} at D={D} "
                                 f"(> INTERPRET_MAX_D={INTERPRET_MAX_D})")
                    continue
                fn = jax.jit(lambda *a, _k=kernel.impl(backend), _kw=kw:
                             _k(*a, **_kw))
                us = min_time_s(fn, *args, repeats=repeats) * 1e6
                rows.append({"kernel": name, "backend": backend,
                             "K": K, "P": P, "D": D, "us_per_call": us})
                obs.progress(f"{name},{backend},{K},{P},{D},{us:.1f}")
    doc = {"bench": "kernels", "backend": jax.default_backend(),
           "smoke": smoke, "repeats": repeats, "rows": rows}
    # smoke runs get their own (untracked) file so a CI-sized run can't
    # silently replace the tracked full-ladder baseline
    name = "BENCH_kernels_smoke.json" if smoke else "BENCH_kernels.json"
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smallest ladder point only)")
    args = ap.parse_args()
    if args.smoke:
        # two smallest ladder points: the D=4096 entries are the ones fat
        # enough (>min-us) for check_regress to actually gate
        run(sizes=SIZES[:2], repeats=30, smoke=True)
    else:
        run()


if __name__ == "__main__":
    main()
