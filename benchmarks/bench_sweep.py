"""Sweep-service benchmark (DESIGN.md §12): what windowing costs and
what resuming saves.

Rows land in ``benchmarks/BENCH_sweep.json`` (``--smoke`` writes the
untracked ``BENCH_sweep_smoke.json`` at the smallest point only):

* ``sweep_oneshot`` — warm one-shot ``run_grid`` over the grid, the
  baseline the windowed path is measured against (``us_per_call`` =
  warm wall / W, so the two gated rows share units);
* ``sweep_windowed`` — warm ``SweepRunner`` pass over the same grid in
  W windows, all in memory: ``us_per_call`` is wall per window (gated),
  ``overhead_vs_oneshot`` the windowed/one-shot wall ratio, and
  ``wall_us_per_window_cold`` the compile-inclusive cold pass
  (recorded, ungated — compile time is machine/XLA-version noise at 2×);
* ``sweep_persisted`` — the same run writing carries + chunks + state
  through the sweep directory every window: ``us_per_call`` per window
  including the atomic checkpoint writes (gated; the delta vs
  ``sweep_windowed`` is the persistence tax);
* ``sweep_resume_reload`` — ``SweepRunner.resume().run()`` over the
  completed directory: pure manifest + npz reload, zero compiles
  (asserted), ``us_per_call`` per window reloaded.

  PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke]
"""
import argparse
import json
import os
import tempfile
import time

import jax

from repro import obs

SEEDS = (0, 1)
ETAS = (1e-3, 5e-3, 1e-2, 2e-2)

# (env_spec, T, W, base config kwargs); the first entry is the smoke point
SWEEP_SIZES = (
    ("cartpole(horizon=20)", 6, 3,
     dict(K=3, n_byz=1, attack="large_noise(sigma=10)", N=4, B=2, kappa=2,
          hidden=(8,))),
    ("cartpole(horizon=100)", 20, 4,
     dict(K=13, n_byz=3, attack="large_noise(sigma=10)", N=20, B=4,
          kappa=4, hidden=(16, 16))),
)


def _row(name, us, derived=""):
    obs.progress(f"{name},{us:.1f},{derived}")


def measure(env_spec: str, T: int, W: int, base: dict) -> list:
    from repro.core import engine
    from repro.core.engine import ScenarioGrid, run_grid
    from repro.rl.envs import make_env
    from repro.sweep import SweepRunner

    env = make_env(env_spec)
    axes = {"eta": ETAS}
    L, S = len(ETAS), len(SEEDS)
    shared = {"env": env_spec, "K": base["K"], "T": T, "L": L, "S": S,
              "W": W}
    rows = []

    def runner(out_dir=None, windows=W):
        return SweepRunner(algo="decbyzpg", env=env_spec, T=T,
                           seeds=SEEDS, axes=axes, windows=windows,
                           out_dir=out_dir, **base)

    # one-shot baseline (warm)
    grid = ScenarioGrid(seeds=SEEDS, axes=axes)
    run_grid(env, grid, T, algo="decbyzpg", **base)
    t0 = time.perf_counter()
    run_grid(env, grid, T, algo="decbyzpg", **base)
    oneshot = time.perf_counter() - t0
    rows.append({"name": "sweep_oneshot",
                 "us_per_call": oneshot * 1e6 / W, **shared})
    _row(f"sweep_oneshot_K{base['K']}_T{T}", oneshot * 1e6 / W,
         f"wall_us={oneshot * 1e6:.0f}")

    # windowed, in memory: cold (compile-inclusive, ungated) then warm
    engine.clear_cache()
    t0 = time.perf_counter()
    runner().run()
    cold = time.perf_counter() - t0
    compiles = engine.compile_count()
    t0 = time.perf_counter()
    runner().run()
    warm = time.perf_counter() - t0
    rows.append({"name": "sweep_windowed",
                 "us_per_call": warm * 1e6 / W,
                 "wall_us_per_window_cold": cold * 1e6 / W,
                 "compiles": compiles,
                 "overhead_vs_oneshot": warm / oneshot, **shared})
    _row(f"sweep_windowed_K{base['K']}_T{T}", warm * 1e6 / W,
         f"W={W};compiles={compiles};"
         f"overhead_vs_oneshot={warm / oneshot:.2f}x")

    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "sweep")
        t0 = time.perf_counter()
        runner(out_dir=out).run()
        persisted = time.perf_counter() - t0
        rows.append({"name": "sweep_persisted",
                     "us_per_call": persisted * 1e6 / W,
                     "persistence_tax_vs_windowed": persisted / warm,
                     **shared})
        _row(f"sweep_persisted_K{base['K']}_T{T}", persisted * 1e6 / W,
             f"tax_vs_windowed={persisted / warm:.2f}x")

        engine.clear_cache()
        t0 = time.perf_counter()
        SweepRunner.resume(out).run()
        reload_ = time.perf_counter() - t0
        assert engine.compile_count() == 0      # pure reload, no engine
        rows.append({"name": "sweep_resume_reload",
                     "us_per_call": reload_ * 1e6 / W,
                     "speedup_vs_persisted": persisted / reload_,
                     **shared})
        _row(f"sweep_resume_reload_K{base['K']}_T{T}", reload_ * 1e6 / W,
             f"speedup_vs_persisted={persisted / reload_:.1f}x;"
             f"compiles=0")
    return rows


def run(smoke: bool = False) -> dict:
    obs.progress("name,us_per_call,derived")
    rows = []
    for env_spec, T, W, base in (SWEEP_SIZES[:1] if smoke
                                 else SWEEP_SIZES):
        rows += measure(env_spec, T, W, base)
    doc = {"bench": "sweep", "backend": jax.default_backend(),
           "smoke": smoke, "etas": list(ETAS), "seeds": list(SEEDS),
           # check_regress.py keys rows through this declaration
           "key_fields": ["name", "env", "K", "T", "L", "S", "W"],
           "rows": rows}
    name = "BENCH_sweep_smoke.json" if smoke else "BENCH_sweep.json"
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smallest point only)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
