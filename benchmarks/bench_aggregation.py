"""Robust-aggregation benchmark: us/call and peak-bytes/device for the
registry aggregators over a D ladder up to transformer scale.

Times each aggregator on a (K, D) stack through three execution paths:

  jnp               dense registry path on the jnp-oracle kernels
  pallas[-interpret] dense registry path on the Pallas kernels (compiled
                    on TPU; the interpreter elsewhere, skipped above
                    ``INTERPRET_MAX_D`` — minutes-slow at model scale)
  flat              the sharded flat execution layer (DESIGN.md §3:
                    local-shard Gram + K² psum, ``sharded=True``) — the
                    path a D-sharded transformer stack takes

The top ladder point is the actual flat parameter count of the reduced
``qwen2.5-3b`` policy/trainer config, so the numbers answer "what does
robust aggregation cost at the scale ``examples/federated_llm.py``
runs at". Alongside wall-clock, each row records the compiled program's
per-device memory footprint (``memory_analysis()``: argument/output/temp
bytes) — the O(K² + K·D/devices) claim of the sharded path is asserted
from these numbers by ``tests/test_flat_aggregation.py``.

Results go to ``benchmarks/BENCH_aggregation.json``; ``--smoke`` runs the
smallest ladder point only and writes the untracked
``BENCH_aggregation_smoke.json`` that ``benchmarks/check_regress.py``
gates CI with (only ``us_per_call`` is gated; byte counts are recorded,
not gated).

  PYTHONPATH=src python -m benchmarks.bench_aggregation [--smoke]
"""
import argparse
import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.timing import min_time_s

from repro import obs

K = 8
N_BYZ = 1
AGGREGATORS = ("krum", "rfa", "trimmed_mean")
#: interpret-mode runs above this D are skipped off-TPU (the interpreter
#: is minutes-slow at model scale; the skip is printed, not silent)
INTERPRET_MAX_D = 4096


def transformer_d() -> int:
    """Flat parameter count of the reduced qwen2.5-3b config — the D the
    federated-LLM example actually aggregates at (deterministic, so the
    ladder key matches across runs)."""
    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    shapes = jax.eval_shape(
        lambda k: init_params(reduced(get_config("qwen2.5-3b")), k),
        jax.random.PRNGKey(0))
    return int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes)))


def ladder() -> tuple:
    # first entry is the smoke shape, so smoke rows always have a matching
    # key in the committed full-ladder baseline
    return (4096, 65536, transformer_d())


def _make_fn(name: str, backend: str, pallas_backend: str):
    """Jitted ``fn(x, key) -> (D,)`` for one (aggregator, path) cell."""
    from repro.core.registry import resolve
    from repro.kernels import dispatch

    if backend == "flat":
        agg = resolve("aggregator", name, K=K, n_byz=N_BYZ, sharded=True)
        return jax.jit(lambda x, k: agg(x, k))
    agg = resolve("aggregator", name, K=K, n_byz=N_BYZ, sharded=False)
    kb = backend if backend != "pallas" else pallas_backend

    def call(x, k):
        # backend dispatch is trace-time, so the context scopes the jit
        with dispatch.use_backend(kb):
            return agg(x, k)

    return jax.jit(call)


def _memory_bytes(fn, *args):
    """Per-device compiled footprint, or Nones where the backend doesn't
    expose memory_analysis()."""
    try:
        ma = fn.lower(*args).compile().memory_analysis()
        return (int(ma.argument_size_in_bytes), int(ma.output_size_in_bytes),
                int(ma.temp_size_in_bytes))
    except Exception:
        return None, None, None


def run(sizes=None, repeats: int = 20, smoke: bool = False) -> dict:
    from repro.kernels import dispatch

    sizes = ladder() if sizes is None else sizes
    pallas_backend = "pallas" if dispatch.on_tpu() else "pallas-interpret"
    key = jax.random.PRNGKey(0)
    rows = []
    obs.progress("aggregator,backend,K,D,us_per_call,temp_bytes")
    for D in sizes:
        x = jax.random.normal(key, (K, D))
        for name in AGGREGATORS:
            for backend in ("jnp", pallas_backend, "flat"):
                if (backend == "pallas-interpret"
                        and D > INTERPRET_MAX_D):
                    obs.progress(f"# skip {name}/{backend} at D={D} "
                                 f"(> INTERPRET_MAX_D={INTERPRET_MAX_D})")
                    continue
                fn = _make_fn(name, backend, pallas_backend)
                us = min_time_s(fn, x, key, repeats=repeats) * 1e6
                arg_b, out_b, temp_b = _memory_bytes(fn, x, key)
                rows.append({"aggregator": name, "backend": backend,
                             "K": K, "D": D, "us_per_call": us,
                             "arg_bytes": arg_b, "out_bytes": out_b,
                             "temp_bytes": temp_b})
                obs.progress(f"{name},{backend},{K},{D},{us:.1f},{temp_b}")
    doc = {"bench": "aggregation", "backend": jax.default_backend(),
           "n_devices": jax.device_count(), "smoke": smoke,
           "repeats": repeats, "rows": rows}
    # smoke runs get their own (untracked) file so a CI-sized run can't
    # silently replace the tracked full-ladder baseline
    name = ("BENCH_aggregation_smoke.json" if smoke
            else "BENCH_aggregation.json")
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    obs.progress(f"# wrote {path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (smallest ladder point only)")
    args = ap.parse_args()
    if args.smoke:
        run(sizes=ladder()[:1], repeats=30, smoke=True)
    else:
        run()


if __name__ == "__main__":
    main()
