"""Serving subsystem: continuous-batching robust policy decode.

The front door is :func:`serve` — load the *aggregated* federated policy
(the artifact the Byzantine-robust training loop agrees on), build a
fixed-slot continuous-batching decode engine for its transformer, and
serve a stream of simulated per-user requests:

    from repro import serving
    report = serving.serve(
        policy="transformer(arch='llama3.2-1b', n_layers=2, d_model=64, "
               "n_heads=2)",
        env="cartpole(horizon=32)",
        checkpoint="results/policy.npz", n_requests=32, slots=4)
    print(report.summary())

Layers (each importable on its own):

* :mod:`repro.serving.request` — request/result types + thread-safe queue
* :mod:`repro.serving.engine` — jitted slot state, tick/insert/prefill
* :mod:`repro.serving.scheduler` — slot lifecycle bookkeeping
* :mod:`repro.serving.server` — offline/realtime loops + obs telemetry
* :mod:`repro.serving.traffic` — simulated Poisson request streams
"""
from __future__ import annotations

from typing import Optional

from repro.serving.engine import (DecodeEngine, SlotState, TickOut,
                                  default_buckets, engine_for_policy)
from repro.serving.request import Request, RequestQueue, RequestResult
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import PolicyServer, ServeReport
from repro.serving.traffic import make_traffic

__all__ = ["DecodeEngine", "SlotState", "TickOut", "default_buckets",
           "engine_for_policy", "Request", "RequestQueue", "RequestResult",
           "SlotScheduler", "PolicyServer", "ServeReport", "make_traffic",
           "serve", "policy_params"]


def policy_params(policy, *, checkpoint: Optional[str] = None, theta=None,
                  key=None):
    """Materialize servable params for a resolved policy.

    Precedence: ``checkpoint`` (a ``repro.checkpoint`` archive of the
    param pytree — the aggregated artifact the trainer saves) >
    ``theta`` (a flat aggregated policy vector, unraveled through the
    policy's own template) > ``key`` (fresh init — caller supplies the
    key; nothing here manufactures PRNG state)."""
    import jax
    import jax.numpy as jnp

    if checkpoint is not None:
        from repro.checkpoint import restore
        template = jax.eval_shape(
            policy.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        return restore(template, checkpoint)
    if theta is not None:
        from repro.rl.policy import policy_unraveler
        unravel, d = policy_unraveler(policy)
        theta = jnp.asarray(theta)
        if theta.shape != (d,):
            raise ValueError(f"theta has shape {theta.shape}, policy "
                             f"expects ({d},)")
        return unravel(theta)
    if key is not None:
        return policy.init(key)
    raise ValueError("no parameter source: pass checkpoint=, theta= or "
                     "key= (serving never invents PRNG state)")


def serve(policy: str = "transformer(arch='llama3.2-1b', n_layers=2, "
                        "d_model=64, n_heads=2)",
          env: str = "cartpole(horizon=32)", *,
          checkpoint: Optional[str] = None, theta=None, key=None,
          params=None, n_requests: int = 32, rate_rps: float = 50.0,
          slots: int = 4, max_new: int = 16, max_prompt: int = 8,
          seed: int = 0, realtime: bool = True, warmup: bool = True,
          **engine_kw) -> ServeReport:
    """Serve simulated policy traffic against the aggregated model.

    ``policy``/``env`` are registry spec strings (the same ``policy=``
    the training configs take); the policy must be servable (attached
    ``model_cfg`` — i.e. a transformer policy).  Parameters come from
    ``params`` directly or :func:`policy_params` (checkpoint > theta >
    key).  ``realtime`` replays Poisson arrivals at ``rate_rps`` against
    the wall clock through the feeder thread; off, the offline
    deterministic loop runs the same continuous-batching schedule on a
    virtual clock."""
    from repro.core.registry import resolve
    from repro.rl.envs import make_env

    e = make_env(env) if isinstance(env, str) else env
    pol = resolve("policy", policy, env=e) if isinstance(policy, str) \
        else policy
    if params is None:
        params = policy_params(pol, checkpoint=checkpoint, theta=theta,
                               key=key)
    engine = engine_for_policy(pol, params, slots=slots, max_new=max_new,
                               max_prompt=max_prompt, **engine_kw)
    server = PolicyServer(engine, warmup=warmup)
    traffic = make_traffic(n_requests, seed=seed, rate_rps=rate_rps,
                           max_new=max_new, obs_dim=e.obs_dim)
    if realtime:
        return server.run(traffic)
    return server.run_offline(traffic)
