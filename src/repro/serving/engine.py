"""Continuous-batching decode engine (maxtext offline-inference style).

One fixed-slot jitted decode state is stepped by a single compiled
``tick`` per scheduler round:

* the **state** (:class:`SlotState`) is a pytree carrying the per-slot
  ring KV cache (``models.init_slot_cache`` — every slot has its own
  write position), the per-slot current token / generated-count / budget
  vectors, and an active mask;
* **tick** runs ``decode_step_slots`` over all slots — active or not —
  so the program shape never depends on occupancy, takes the greedy
  next token per slot, and retires slots whose budget is exhausted;
* **insert** writes one request's prefilled batch-1 ring into a free
  slot (``distributed.serving.slot_cache_insert``); slot index, true
  prompt length and budget are traced scalars, so one compiled insert
  program serves every slot and prompt length;
* **prefill** is compiled once per prompt-length *bucket*: prompts are
  right-padded up to the bucket, causality keeps the real positions
  exact, the padded ring entries are invalidated on insert, and the
  first token is read at the true last position.

Exactly three program families exist (prefill-per-bucket, insert, tick);
after :meth:`DecodeEngine.warmup` a request stream triggers zero XLA
compiles (pinned by ``tests/test_serving.py`` with the PR-7
``CompileLog``).  Decode is greedy by design: the served policy is the
*agreed* aggregated model, so identical requests must yield identical
tokens on every replica (the batching-invariance contract).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import donate_args
from repro.distributed.serving import slot_cache_evict, slot_cache_insert
from repro.models.model import decode_step_slots, init_slot_cache, prefill
from repro.serving.request import Request

#: BOS anchor supplied when a request carries only an observation
BOS_ID = 0


class SlotState(NamedTuple):
    """The jitted decode state: one pytree, one device round-trip per
    tick."""
    cache: dict          # per-slot ring cache (models.init_slot_cache)
    tokens: jnp.ndarray  # (S,) int32 — token to feed each slot next
    steps: jnp.ndarray   # (S,) int32 — tokens generated so far
    budget: jnp.ndarray  # (S,) int32 — max_new per slot
    active: jnp.ndarray  # (S,) bool


class TickOut(NamedTuple):
    """Host view of one tick: per-slot emissions."""
    tokens: np.ndarray   # (S,) next token per slot (frozen where inactive)
    done: np.ndarray     # (S,) bool — slot retired this tick
    active: np.ndarray   # (S,) bool — slot was active entering the tick


def default_buckets(max_prompt: int) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets covering [1, max_prompt]."""
    out = []
    b = 1
    while b < max_prompt:
        out.append(b)
        b *= 2
    out.append(max_prompt)
    return tuple(dict.fromkeys(out))


class DecodeEngine:
    """Fixed-slot continuous-batching greedy decoder for one model.

    ``n_logits`` restricts the greedy argmax to the first ``n_logits``
    vocabulary entries — the action head of a transformer *policy*
    (``rl.transformer_policy``), whose logits are the leading
    ``env.n_actions`` entries of the LM head.

    Recurrent families (``ssm`` / ``hybrid``) cannot be prompt-padded —
    state pollution from pad steps is not maskable after the fact — so
    their buckets degenerate to exact prompt lengths (one prefill
    compile per distinct length; attention families pay one per bucket).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_new: int = 32, max_prompt: int = 64,
                 prompt_buckets: Optional[Tuple[int, ...]] = None,
                 n_logits: Optional[int] = None, dtype=jnp.float32):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.max_new = int(max_new)
        self.max_prompt = int(max_prompt)
        self.n_logits = None if n_logits is None else int(n_logits)
        self.dtype = dtype
        self.has_pe = cfg.frontend != "none"
        self._pad_ok = cfg.family not in ("ssm", "hybrid")
        if prompt_buckets is None:
            prompt_buckets = default_buckets(max_prompt) if self._pad_ok \
                else ()
        self.prompt_buckets = tuple(sorted(prompt_buckets))
        #: ring size: longest padded prompt + full generation budget
        self.cache_len = (cfg.n_prefix_embeds
                          + (max(self.prompt_buckets)
                             if self.prompt_buckets else max_prompt)
                          + max_new)

        self._tick_jit = jax.jit(self._tick_impl,
                                 donate_argnums=donate_args(1))
        self._insert_jit = jax.jit(self._insert_impl,
                                   donate_argnums=donate_args(0))
        self._evict_jit = jax.jit(self._evict_impl,
                                  donate_argnums=donate_args(0))
        self._prefill_jit: dict = {}     # bucket len -> compiled prefill

    # -- state ------------------------------------------------------------

    def init_state(self) -> SlotState:
        S = self.slots
        return SlotState(
            cache=init_slot_cache(self.cfg, S, self.cache_len, self.dtype),
            tokens=jnp.zeros((S,), jnp.int32),
            steps=jnp.zeros((S,), jnp.int32),
            budget=jnp.zeros((S,), jnp.int32),
            active=jnp.zeros((S,), jnp.bool_))

    def update_params(self, params) -> None:
        """Hot-swap the served policy (e.g. a fresh aggregated model from
        the federated trainer) — params are a traced argument of every
        program, so no recompilation."""
        self.params = params

    # -- traced programs --------------------------------------------------

    def _greedy(self, logits):
        if self.n_logits is not None:
            logits = logits[..., :self.n_logits]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _tick_impl(self, params, state: SlotState):
        logits, cache = decode_step_slots(self.cfg, params, state.tokens,
                                          state.cache)
        nxt = self._greedy(logits)
        nxt = jnp.where(state.active, nxt, state.tokens)
        steps = state.steps + state.active
        done = state.active & (steps >= state.budget)
        new = SlotState(cache=cache, tokens=nxt, steps=steps,
                        budget=state.budget, active=state.active & ~done)
        return new, nxt, done, state.active

    def _insert_impl(self, state: SlotState, slot, row_cache, first_tok,
                     true_len, budget):
        cache = slot_cache_insert(state.cache, row_cache, slot, true_len)
        return SlotState(
            cache=cache,
            tokens=state.tokens.at[slot].set(first_tok),
            steps=state.steps.at[slot].set(1),
            budget=state.budget.at[slot].set(budget),
            active=state.active.at[slot].set(True))

    def _evict_impl(self, state: SlotState, slot):
        return SlotState(cache=slot_cache_evict(state.cache, slot),
                         tokens=state.tokens, steps=state.steps,
                         budget=state.budget,
                         active=state.active.at[slot].set(False))

    def _prefill_for(self, padded_len: int):
        fn = self._prefill_jit.get(padded_len)
        if fn is not None:
            return fn
        cfg, W = self.cfg, self.cache_len

        def pf_pe(params, toks, pe, true_total):
            logits, cache = prefill(cfg, params, toks, pe, cache_len=W,
                                    last_only=False)
            first = self._greedy(logits[0, true_total - 1])
            return first, cache

        def pf(params, toks, true_total):
            logits, cache = prefill(cfg, params, toks, None, cache_len=W,
                                    last_only=False)
            first = self._greedy(logits[0, true_total - 1])
            return first, cache

        fn = jax.jit(pf_pe if self.has_pe else pf)
        self._prefill_jit[padded_len] = fn
        return fn

    # -- host API ---------------------------------------------------------

    def bucket_for(self, prompt_len: int) -> int:
        """Padded token length for a prompt of ``prompt_len`` tokens."""
        if prompt_len > self.max_prompt:
            raise ValueError(f"prompt of {prompt_len} tokens exceeds "
                             f"max_prompt={self.max_prompt}")
        if not self._pad_ok:
            return prompt_len          # recurrent state: no padding
        for b in self.prompt_buckets:
            if prompt_len <= b:
                return b
        return prompt_len

    def _prompt(self, req: Request):
        toks = req.tokens if req.tokens is not None \
            else np.asarray([BOS_ID], np.int32)
        if req.obs is not None and not self.has_pe:
            raise ValueError(f"request {req.uid} carries an observation "
                             f"but {self.cfg.name} has no prefix-embedding "
                             f"frontend")
        P = len(toks)
        padded = self.bucket_for(P)
        toks = np.pad(toks, (0, padded - P))[None]        # (1, padded)
        pe = None
        if self.has_pe:
            pe = np.zeros((1, self.cfg.n_prefix_embeds, self.cfg.d_model),
                          np.float32)
            if req.obs is not None:
                pe[0, 0, :req.obs.shape[0]] = req.obs
        true_total = self.cfg.n_prefix_embeds + P
        return toks, pe, true_total, padded

    def prefill_request(self, req: Request):
        """Run one request's prompt. Returns ``(first_token int,
        row_cache, true_total)`` — the insert-ready batch-1 ring."""
        toks, pe, true_total, padded = self._prompt(req)
        pf = self._prefill_for(padded)
        if self.has_pe:
            first, row = pf(self.params, toks, pe, true_total)
        else:
            first, row = pf(self.params, toks, true_total)
        return int(first), row, true_total

    def insert(self, state: SlotState, slot: int, row_cache, first_tok,
               true_total: int, max_new: int) -> SlotState:
        if max_new > self.max_new:
            raise ValueError(f"max_new={max_new} exceeds engine budget "
                             f"{self.max_new}")
        return self._insert_jit(state, slot, row_cache, first_tok,
                                true_total, max_new)

    def evict(self, state: SlotState, slot: int) -> SlotState:
        """Cancel a slot mid-flight (finished slots retire themselves in
        the tick — this is for cancellations/resets)."""
        return self._evict_jit(state, slot)

    def tick(self, state: SlotState):
        """One decode step for every slot. Returns ``(state, TickOut)``."""
        state, nxt, done, active = self._tick_jit(self.params, state)
        return state, TickOut(tokens=np.asarray(nxt),
                              done=np.asarray(done),
                              active=np.asarray(active))

    def warmup(self, buckets: Optional[Tuple[int, ...]] = None) -> int:
        """Compile every program family against a scratch state: one
        prefill per bucket, the shared insert, the tick, the evict.
        Returns the number of programs warmed."""
        state = self.init_state()
        if buckets is None:
            buckets = self.prompt_buckets or (min(1, self.max_prompt) or 1,)
        n = 0
        for b in buckets:
            req = Request(uid=-1, max_new=2,
                          tokens=np.zeros((min(b, self.max_prompt),),
                                          np.int32),
                          obs=(np.zeros((1,), np.float32)
                               if self.has_pe else None))
            first, row, true_total = self.prefill_request(req)
            state = self.insert(state, 0, row, first, true_total, 2)
            n += 1
        state, _ = self.tick(state)
        state = self.evict(state, 0)
        return n + 3


def engine_for_policy(policy, params=None, **kw) -> DecodeEngine:
    """Build a :class:`DecodeEngine` serving a resolved servable policy
    (one with ``model_cfg``, e.g. ``policy="transformer(...)"``), with
    the greedy head restricted to the policy's action logits."""
    model_cfg = getattr(policy, "model_cfg", None)
    if model_cfg is None:
        raise ValueError("policy is not servable: no model_cfg attached "
                         "(only transformer policies decode; 'mlp' has no "
                         "token stream)")
    kw.setdefault("n_logits", getattr(policy, "n_actions", None))
    return DecodeEngine(model_cfg, params, **kw)


def _unused():       # pragma: no cover — keeps dataclasses import honest
    return dataclasses.MISSING
