"""Simulated per-user traffic for the serving benchmark and examples.

Arrivals are a Poisson process (exponential inter-arrival gaps at
``rate_rps``), prompts are drawn from a small set of lengths, and —
when serving a *policy* — each request carries a synthetic observation
vector that the engine maps into the model's prefix-embedding frontend.

Everything here is host-side ``numpy.random.default_rng`` state: traffic
is simulation input, not model state, so it never touches jax PRNG keys
(``repro.analysis`` lints key hygiene in ``src/``; a generator seeded
once here keeps the stream reproducible without key plumbing).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


def make_traffic(n_requests: int, *, seed: int = 0, rate_rps: float = 50.0,
                 max_new: int = 16, obs_dim: Optional[int] = None,
                 prompt_lens: Sequence[int] = (1, 4, 8, 16),
                 vocab: int = 256,
                 jitter_budget: bool = True) -> List[Request]:
    """Generate ``n_requests`` requests with staggered Poisson arrivals.

    ``obs_dim`` set → policy traffic: requests carry an observation (the
    engine supplies the BOS anchor) and no token prompt.  ``obs_dim``
    None → LM traffic: token prompts of lengths drawn from
    ``prompt_lens``.  ``jitter_budget`` varies per-request ``max_new``
    in ``[max(1, max_new // 2), max_new]`` so completions stagger and
    slots actually recycle mid-stream.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]          # first arrives at t=0
    reqs = []
    for i in range(n_requests):
        budget = int(rng.integers(max(1, max_new // 2), max_new + 1)) \
            if jitter_budget else max_new
        if obs_dim is not None:
            obs = rng.standard_normal(obs_dim).astype(np.float32)
            reqs.append(Request(uid=i, max_new=budget, obs=obs,
                                arrival_s=float(arrivals[i])))
        else:
            P = int(rng.choice(np.asarray(prompt_lens)))
            toks = rng.integers(0, vocab, size=P).astype(np.int32)
            reqs.append(Request(uid=i, max_new=budget, tokens=toks,
                                arrival_s=float(arrivals[i])))
    return reqs
