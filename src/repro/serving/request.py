"""Request/response types and the thread-safe submission queue.

A :class:`Request` is one simulated user's decode job: a prompt (token
ids, an observation that becomes a prefix embedding, or both) plus a
token budget.  The engine is greedy by construction — the served artifact
is the *aggregated* federated policy, which every honest agent agrees on,
so two replicas serving the same request must return the same tokens.

Timestamps are wall-clock seconds (``time.monotonic``); latency is
``t_done - t_submit``, i.e. queueing + prefill + decode as the user sees
it.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One decode job.

    ``tokens`` — prompt token ids ``(S,)`` (``None`` for obs-only
    policy requests, where the BOS anchor is supplied by the engine);
    ``obs`` — observation vector mapped into the model's prefix-embedding
    frontend (requires ``cfg.frontend != "none"``);
    ``max_new`` — number of tokens to generate (>= 1);
    ``arrival_s`` — offset from stream start at which the traffic
    generator submits this request (ignored in offline replay).
    """
    uid: int
    max_new: int = 16
    tokens: Optional[np.ndarray] = None
    obs: Optional[np.ndarray] = None
    arrival_s: float = 0.0

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError(f"request {self.uid}: max_new must be >= 1, "
                             f"got {self.max_new}")
        if self.tokens is None and self.obs is None:
            raise ValueError(f"request {self.uid}: needs tokens and/or obs")
        if self.tokens is not None:
            self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.obs is not None:
            self.obs = np.asarray(self.obs, np.float32).reshape(-1)


@dataclasses.dataclass
class RequestResult:
    """Completed request: generated tokens + per-phase timestamps."""
    uid: int
    tokens: List[int]
    prompt_len: int                  # real prompt positions (prefix incl.)
    t_submit: float = 0.0
    t_admit: float = 0.0             # prefilled into a slot
    t_first: float = 0.0             # first token available
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Time to first token (queueing + prefill)."""
        return self.t_first - self.t_submit

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit


class RequestQueue:
    """Thread-safe FIFO between feeder threads and the engine loop."""

    def __init__(self):
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._submitted = 0
        self._lock = threading.Lock()

    def put(self, req: Request) -> None:
        with self._lock:
            self._submitted += 1
        self._q.put(req)

    def get_nowait(self) -> Optional[Request]:
        try:
            return self._q.get_nowait()
        except queue.Empty:
            return None

    def get(self, timeout: float) -> Optional[Request]:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def depth(self) -> int:
        return self._q.qsize()

    @property
    def submitted(self) -> int:
        with self._lock:
            return self._submitted
