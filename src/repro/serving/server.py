"""Server loop: feeder + engine threads around the slot scheduler.

Two execution modes share the same scheduler:

* **offline** (:meth:`PolicyServer.run_offline`) — single-threaded,
  virtual-clock replay of a request list in arrival order.  Admission
  is interleaved with decode exactly as online continuous batching
  would do it (admit while a slot is free, tick otherwise), but with no
  wall-clock dependence — this is the mode the invariance tests and the
  benchmark use.
* **realtime** (:meth:`PolicyServer.run`) — a feeder thread replays
  each request's ``arrival_s`` offset against the wall clock into the
  thread-safe :class:`~repro.serving.request.RequestQueue`; the engine
  thread admits from the queue whenever a slot is free and otherwise
  ticks.  Latency percentiles from this mode include real queueing
  delay, which is what the serving benchmark reports.

Observability (zero-overhead-off, PR-8 conventions): per-request
``serve.request`` records and ``serve.gauge`` queue-depth/slot-occupancy
gauges are emitted only under ``obs.enabled()``; the end-of-run summary
goes through ``obs.progress``.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

from repro import obs
from repro.serving.engine import DecodeEngine
from repro.serving.request import Request, RequestQueue, RequestResult
from repro.serving.scheduler import SlotScheduler


def _percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile without numpy-on-hot-path ceremony."""
    if not xs:
        return 0.0
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[idx]


class ServeReport:
    """Aggregate view over one served request stream."""

    def __init__(self, results: List[RequestResult], wall_s: float):
        self.results = sorted(results, key=lambda r: r.uid)
        self.wall_s = wall_s
        lats = [r.latency_s for r in self.results]
        self.n_requests = len(self.results)
        self.total_tokens = sum(len(r.tokens) for r in self.results)
        self.latency_p50_s = _percentile(lats, 50)
        self.latency_p99_s = _percentile(lats, 99)
        self.ttft_p50_s = _percentile([r.ttft_s for r in self.results], 50)
        self.tokens_per_s = self.total_tokens / wall_s if wall_s > 0 else 0.0

    def summary(self) -> dict:
        return {"n_requests": self.n_requests,
                "total_tokens": self.total_tokens,
                "wall_s": round(self.wall_s, 4),
                "tokens_per_s": round(self.tokens_per_s, 2),
                "latency_p50_ms": round(self.latency_p50_s * 1e3, 3),
                "latency_p99_ms": round(self.latency_p99_s * 1e3, 3),
                "ttft_p50_ms": round(self.ttft_p50_s * 1e3, 3)}


class PolicyServer:
    """Continuous-batching server over one :class:`DecodeEngine`."""

    def __init__(self, engine: DecodeEngine, warmup: bool = True):
        self.engine = engine
        self.scheduler = SlotScheduler(engine)
        self.queue = RequestQueue()
        if warmup:
            with obs.host_span("serve.warmup"):
                engine.warmup()
            self.scheduler = SlotScheduler(engine)   # fresh post-warmup state

    # -- shared bookkeeping ------------------------------------------------

    def _emit_done(self, res: RequestResult) -> None:
        if obs.enabled():
            obs.record("serve.request", uid=res.uid,
                       tokens=len(res.tokens), prompt_len=res.prompt_len,
                       latency_ms=round(res.latency_s * 1e3, 3),
                       ttft_ms=round(res.ttft_s * 1e3, 3),
                       queue_ms=round(res.queue_s * 1e3, 3))

    def _emit_gauges(self) -> None:
        if obs.enabled():
            obs.record("serve.gauge", queue_depth=self.queue.depth(),
                       slots_busy=self.scheduler.busy(),
                       slots=self.engine.slots)

    # -- offline -----------------------------------------------------------

    def run_offline(self, requests: Sequence[Request],
                    submit_at_arrival: bool = False) -> ServeReport:
        """Deterministic single-threaded replay. Requests are admitted in
        arrival order whenever a slot frees up.  By default ``t_submit``
        is stamped at admission, so offline latency is pure service time
        (prefill + decode) — the loop runs faster than the declared
        arrival offsets, which makes queueing delay meaningless here;
        use :meth:`run` for latency that includes real queueing."""
        t0 = time.monotonic()
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.uid))
        done: List[RequestResult] = []
        i = 0
        while i < len(pending) or not self.scheduler.idle():
            while i < len(pending) and self.scheduler.has_free():
                req = pending[i]
                i += 1
                t_submit = (t0 + req.arrival_s) if submit_at_arrival \
                    else None
                res = self.scheduler.admit(req, t_submit=t_submit)
                if res is not None:
                    done.append(res)
                    self._emit_done(res)
            for res in self.scheduler.tick():
                done.append(res)
                self._emit_done(res)
            self._emit_gauges()
        report = ServeReport(done, time.monotonic() - t0)
        obs.progress("serve.done", mode="offline", **report.summary())
        return report

    # -- realtime ----------------------------------------------------------

    def _feeder(self, requests: Sequence[Request], t0: float) -> None:
        for req in sorted(requests, key=lambda r: (r.arrival_s, r.uid)):
            delay = (t0 + req.arrival_s) - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            self.queue.put(req)

    def run(self, requests: Sequence[Request],
            idle_timeout_s: float = 0.002) -> ServeReport:
        """Realtime replay: a feeder thread submits at each request's
        ``arrival_s`` offset, the calling thread runs the engine loop."""
        t0 = time.monotonic()
        n_total = len(requests)
        submit_times: dict = {}
        feeder = threading.Thread(target=self._feeder, args=(requests, t0),
                                  daemon=True)
        feeder.start()
        done: List[RequestResult] = []
        while len(done) < n_total:
            admitted = False
            while self.scheduler.has_free():
                req = self.queue.get_nowait()
                if req is None:
                    break
                submit_times[req.uid] = t0 + req.arrival_s
                res = self.scheduler.admit(req,
                                           t_submit=submit_times[req.uid])
                admitted = True
                if res is not None:
                    done.append(res)
                    self._emit_done(res)
            if not self.scheduler.idle():
                for res in self.scheduler.tick():
                    done.append(res)
                    self._emit_done(res)
            elif not admitted:
                # nothing in flight, nothing admitted: block briefly on
                # the queue instead of spinning
                req = self.queue.get(timeout=idle_timeout_s)
                if req is not None:
                    submit_times[req.uid] = t0 + req.arrival_s
                    res = self.scheduler.admit(
                        req, t_submit=submit_times[req.uid])
                    if res is not None:
                        done.append(res)
                        self._emit_done(res)
            self._emit_gauges()
        feeder.join()
        report = ServeReport(done, time.monotonic() - t0)
        obs.progress("serve.done", mode="realtime", **report.summary())
        return report
