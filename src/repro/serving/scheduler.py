"""Slot scheduler: maps a request stream onto the engine's fixed slots.

The scheduler owns the jitted :class:`~repro.serving.engine.SlotState`
and the host-side bookkeeping the state cannot carry: which request
occupies which slot, the tokens emitted so far, and per-phase
timestamps.  Its contract with the server loop is small:

* :meth:`admit` prefills one request into a free slot (or completes it
  outright when the budget is a single token — the prefill already
  produced it);
* :meth:`tick` advances every slot one decode step and returns the
  requests that finished this step, freeing their slots;
* :meth:`drain` ticks until nothing is in flight.

A slot's lifecycle is ``free → (prefill+insert) → decoding → done →
free``.  Finished slots retire *inside* the jitted tick (the active
mask flips), so eviction is not a separate device call on the hot path
— the freed slot's ring is simply overwritten by the next insert.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

from repro.serving.engine import DecodeEngine
from repro.serving.request import Request, RequestResult


@dataclasses.dataclass
class _InFlight:
    req: Request
    tokens: List[int]
    prompt_len: int
    t_submit: float
    t_admit: float
    t_first: float


class SlotScheduler:
    """Host-side slot bookkeeping around one :class:`DecodeEngine`."""

    def __init__(self, engine: DecodeEngine,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.clock = clock
        self.state = engine.init_state()
        self.free: List[int] = list(range(engine.slots))
        self.inflight: dict = {}          # slot -> _InFlight

    # -- queries ----------------------------------------------------------

    def has_free(self) -> bool:
        return bool(self.free)

    def busy(self) -> int:
        return len(self.inflight)

    def idle(self) -> bool:
        return not self.inflight

    # -- transitions ------------------------------------------------------

    def admit(self, req: Request,
              t_submit: Optional[float] = None) -> Optional[RequestResult]:
        """Prefill ``req`` and occupy a free slot.  Returns the finished
        :class:`RequestResult` immediately when ``max_new == 1`` (the
        prefill's last-position argmax IS the whole generation), else
        ``None`` — the request completes through :meth:`tick`."""
        now = self.clock()
        t_submit = now if t_submit is None else t_submit
        first, row, true_total = self.engine.prefill_request(req)
        t_first = self.clock()
        if req.max_new == 1:
            return RequestResult(uid=req.uid, tokens=[first],
                                 prompt_len=true_total,
                                 t_submit=t_submit, t_admit=now,
                                 t_first=t_first, t_done=t_first)
        if not self.free:
            raise RuntimeError("admit() with no free slot — gate on "
                               "has_free()")
        slot = self.free.pop()
        self.state = self.engine.insert(self.state, slot, row, first,
                                        true_total, req.max_new)
        self.inflight[slot] = _InFlight(req=req, tokens=[first],
                                        prompt_len=true_total,
                                        t_submit=t_submit, t_admit=now,
                                        t_first=t_first)
        return None

    def tick(self) -> List[RequestResult]:
        """One decode step for all slots; returns requests that finished."""
        if not self.inflight:
            return []
        self.state, out = self.engine.tick(self.state)
        now = self.clock()
        finished = []
        for slot, fl in list(self.inflight.items()):
            if out.active[slot]:
                fl.tokens.append(int(out.tokens[slot]))
            if out.done[slot]:
                finished.append(RequestResult(
                    uid=fl.req.uid, tokens=fl.tokens,
                    prompt_len=fl.prompt_len, t_submit=fl.t_submit,
                    t_admit=fl.t_admit, t_first=fl.t_first, t_done=now))
                del self.inflight[slot]
                self.free.append(slot)
        return finished

    def cancel(self, slot: int) -> None:
        """Drop a slot mid-flight (no result is produced)."""
        if slot in self.inflight:
            self.state = self.engine.evict(self.state, slot)
            del self.inflight[slot]
            self.free.append(slot)

    def drain(self, max_ticks: Optional[int] = None) -> List[RequestResult]:
        """Tick until every in-flight request completes."""
        done: List[RequestResult] = []
        ticks = 0
        while self.inflight:
            done.extend(self.tick())
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                raise RuntimeError(
                    f"drain() exceeded {max_ticks} ticks with "
                    f"{len(self.inflight)} slots still active")
        return done
