"""Deterministic synthetic token pipeline, shard/agent-aware.

Produces {tokens, labels[, prefix_embeds]} batches shaped for the federated
trainer ((K, b, S)) or serving ((B, S)). Content is a cheap
counter-hash stream (Philox via jax.random on host, device_put'ed with the
right sharding) so every run is reproducible and every agent sees a
disjoint shard — a stand-in for a real corpus loader with identical
interface semantics (global determinism, per-agent sharding, resumable by
step index).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    per_agent_batch: int
    n_agents: int = 1
    n_prefix_embeds: int = 0
    d_model: int = 0
    seed: int = 0


class TokenPipeline:
    """Stateless by-step batch source: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: DataConfig, shardings=None):
        self.cfg = cfg
        self.shardings = shardings or {}

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = self._rng(step)
        shape = (c.n_agents, c.per_agent_batch, c.seq_len)
        tokens = rng.integers(0, c.vocab_size, size=shape, dtype=np.int32)
        # next-token targets of the same stream
        labels = np.concatenate(
            [tokens[..., 1:],
             rng.integers(0, c.vocab_size, size=shape[:-1] + (1,),
                          dtype=np.int32)], axis=-1)
        out = {"tokens": tokens, "labels": labels}
        if c.n_prefix_embeds:
            out["prefix_embeds"] = rng.standard_normal(
                (c.n_agents, c.per_agent_batch, c.n_prefix_embeds,
                 c.d_model)).astype(np.float32)
        return {k: (jax.device_put(v, self.shardings[k])
                    if k in self.shardings else jnp.asarray(v))
                for k, v in out.items()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
