"""Shared finding type for the analysis passes.

Every pass (jaxpr walkers, compile audits, AST lint) reports problems as
:class:`Finding` records with a file/line anchor, so the CLI and the tests
can treat all passes uniformly: a pass is a callable returning
``list[Finding]``, and an empty list means the contract holds.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation.

    ``pass_name`` is the reporting pass (``keycheck``, ``retrace``,
    ``donation``, ``memcheck``, ``lint``); ``rule`` the specific contract
    within it.  ``path``/``line`` anchor the violation — for jaxpr passes
    the line points at the offending primitive's user frame, for the lint
    at the AST node.  ``line`` may be 0 when no source location applies
    (e.g. a whole-program contract).
    """

    pass_name: str
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.pass_name}/{self.rule}] {self.message}"


def render(findings: Iterable[Finding]) -> str:
    return "\n".join(f.format() for f in findings)
