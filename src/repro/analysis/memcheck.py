"""Memory contracts: per-device footprint bounds on compiled programs.

``tests/test_flat_aggregation.py`` proved once, for one mesh, that the
sharded flat aggregators compile to an O(K² + K·D/devices) per-device
footprint.  This pass turns that one-off assertion into a declarative
contract table checked over CI-faked mesh sizes: for every
(aggregator, K, devices) contract the compiled program's

* ``argument_size_in_bytes`` must stay within one agent-stack *shard*
  (K·D·4 / devices) plus a small fixed slack — the program must never
  gather the full (K, D) stack onto one device;
* ``temp_size_in_bytes`` must stay within ``temp_factor`` × (shard +
  K²·4) — temporaries are a small multiple of one shard plus the K×K
  score/distance matrix.

Faking devices requires ``XLA_FLAGS=--xla_force_host_platform_device_count``
to be set *before* jax initializes, so :func:`run` executes the checks in
a subprocess (``python -m repro.analysis.memcheck``) and parses JSON
findings from its stdout; the in-process entry point is :func:`child_main`.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Optional

from repro.analysis.findings import Finding

_AGG_PATH = "src/repro/distributed/aggregation.py"
_MARK = "MEMCHECK_JSON:"


@dataclasses.dataclass(frozen=True)
class MemContract:
    """One compiled-program footprint bound on a faked ``devices``-way mesh.

    Bounds (bytes, per device, f32 stacks):

    * arguments ≤ ``K*D*4 / devices + arg_slack``
    * temporaries ≤ ``temp_factor * (K*D*4 / devices + K*K*4)``
    """
    aggregator: str
    K: int
    devices: int
    arg_slack: int = 4096
    temp_factor: int = 4

    @property
    def name(self) -> str:
        return f"{self.aggregator}(K={self.K})@{self.devices}dev"


def contracts() -> list:
    """The contract table: both CI-faked mesh sizes, both flat-path
    aggregators the seed test covered, plus the K used by the paper-scale
    federated runs (K=8)."""
    out = []
    for devices in (2, 4):
        for agg in ("krum", "rfa"):
            out.append(MemContract(aggregator=agg, K=8, devices=devices))
    return out


# ---------------------------------------------------------------------------
# Child side (runs under the forced-device-count XLA flag)
# ---------------------------------------------------------------------------


def _check_contracts(table) -> list:
    """Evaluate contracts in-process; requires ≥ max devices available.
    Returns findings as plain dicts (JSON-portable)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs.base import get_config, reduced
    from repro.core.registry import resolve
    from repro.models.model import init_params

    # reduced-transformer D: the realistic "large model" scale for CI
    shapes = jax.eval_shape(
        lambda k: init_params(reduced(get_config("qwen2.5-3b")), k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    D = int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes)))

    findings = []
    for c in table:
        if c.devices > len(jax.devices()):
            findings.append(dict(
                rule="mesh-unavailable",
                message=f"[{c.name}] contract needs {c.devices} devices "
                        f"but only {len(jax.devices())} are visible — the "
                        f"memcheck subprocess must force "
                        f"--xla_force_host_platform_device_count"))
            continue
        mesh = Mesh(np.asarray(jax.devices()[:c.devices]), ("model",))
        sh = NamedSharding(mesh, P(None, "model"))
        agg = resolve("aggregator", c.aggregator, K=c.K, n_byz=1,
                      sharded=True)
        f = jax.jit(lambda a, k: agg(a, k), in_shardings=(sh, None),
                    out_shardings=NamedSharding(mesh, P("model")))
        xs = jax.ShapeDtypeStruct((c.K, D), jnp.float32)
        ks = jax.ShapeDtypeStruct((2,), jnp.uint32)
        ma = f.lower(xs, ks).compile().memory_analysis()
        shard = c.K * D * 4 // c.devices
        arg_bound = shard + c.arg_slack
        temp_bound = c.temp_factor * (shard + c.K * c.K * 4)
        if ma.argument_size_in_bytes > arg_bound:
            findings.append(dict(
                rule="argument-footprint",
                message=f"[{c.name}] arguments occupy "
                        f"{ma.argument_size_in_bytes} bytes > bound "
                        f"{arg_bound} (one K·D/devices shard + "
                        f"{c.arg_slack}) — the flat path is gathering the "
                        f"full (K, D) stack instead of staying sharded"))
        if ma.temp_size_in_bytes > temp_bound:
            findings.append(dict(
                rule="temp-footprint",
                message=f"[{c.name}] temporaries occupy "
                        f"{ma.temp_size_in_bytes} bytes > bound "
                        f"{temp_bound} ({c.temp_factor}·(shard + K²·4)) — "
                        f"intermediate buffers exceed "
                        f"O(K² + K·D/devices)"))
    return findings


def child_main() -> int:
    """Entry for the forced-device-count subprocess: print one
    ``MEMCHECK_JSON: [...]`` line and exit 0 (findings are data, not a
    crash)."""
    findings = _check_contracts(contracts())
    print(_MARK + json.dumps(findings))
    return 0


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------


def run(root: Optional[Path] = None, devices: int = 4,
        timeout: int = 1200) -> list:
    """Spawn the forced-device subprocess and lift its JSON findings."""
    from repro.analysis.lint import repo_root
    root = root or repo_root()
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    src = str(root / "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.memcheck"],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        return [Finding("memcheck", "subprocess-crash", _AGG_PATH, 0,
                        f"memcheck child exited {proc.returncode}: "
                        f"{proc.stderr[-1000:]}")]
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            raw = json.loads(line[len(_MARK):])
            return [Finding("memcheck", f["rule"], _AGG_PATH, 0,
                            f["message"]) for f in raw]
    return [Finding("memcheck", "subprocess-protocol", _AGG_PATH, 0,
                    "memcheck child produced no MEMCHECK_JSON line: "
                    + proc.stdout[-500:])]


if __name__ == "__main__":
    sys.exit(child_main())
