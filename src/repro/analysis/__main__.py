"""``python -m repro.analysis`` — run the JAX-aware static-analysis suite.

Runs every pass (or a ``--passes`` subset), prints findings as
``path:line: [pass/rule] message`` and exits nonzero iff any pass found
anything.  This is the tier-1 CI gate (see ``.github/workflows/ci.yml``);
the same passes are unit-tested against deliberately-broken fixtures in
``tests/test_analysis_*.py``.

Passes
------
* ``lint``      AST lint over the repo (PRNG literals, spec strings,
                pallas_call location, numpy-on-traced, smoke files)
* ``keycheck``  jaxpr PRNG-key dataflow over the fused loop builders
* ``retrace``   static cache-key hygiene + dynamic compile-count gate
* ``donation``  forced-donation aliasing audit of donate_argnums sites
* ``memcheck``  per-device memory contracts on a faked multi-device mesh
"""

from __future__ import annotations

import argparse
import sys
import time


def _pass_lint():
    from repro.analysis import lint
    return lint.run()


def _pass_keycheck():
    from repro.analysis import keycheck
    return keycheck.run()


def _pass_retrace():
    from repro.analysis import retrace
    return retrace.run()


def _pass_donation():
    from repro.analysis import donation
    return donation.run()


def _pass_memcheck():
    from repro.analysis import memcheck
    return memcheck.run()


# cheap/pure passes first so a lint failure reports before the slow
# trace/compile passes run
PASSES = {
    "lint": _pass_lint,
    "keycheck": _pass_keycheck,
    "retrace": _pass_retrace,
    "donation": _pass_donation,
    "memcheck": _pass_memcheck,
}


def main(argv=None) -> int:
    from repro.analysis.findings import render
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static-analysis suite (see repro.analysis)")
    parser.add_argument(
        "--passes", default=",".join(PASSES),
        help="comma-separated subset of: " + ", ".join(PASSES))
    args = parser.parse_args(argv)
    names = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in names if p not in PASSES]
    if unknown:
        parser.error(f"unknown pass(es): {', '.join(unknown)}")

    all_findings = []
    for name in names:
        t0 = time.monotonic()
        findings = PASSES[name]()
        dt = time.monotonic() - t0
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"[analysis] {name:<9} {status} ({dt:.1f}s)", file=sys.stderr)
        all_findings.extend(findings)
    if all_findings:
        print(render(all_findings))
        return 1
    print(f"[analysis] clean: {len(names)} pass(es), 0 findings",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
