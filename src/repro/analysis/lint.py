"""Repo-specific AST lint (analysis layer 2, DESIGN.md §7).

A small visitor framework: every rule is a :class:`Rule` subclass scoped
to a set of path prefixes; the driver parses each python file (and each
fenced ``python`` block in README.md / DESIGN.md) once into a
:class:`FileCtx` and dispatches it to the rules that claim it.  Rules:

* ``literal-prng-key`` — no literal ``jax.random.PRNGKey(<const>)`` (or
  ``jax.random.key``) in library code under ``src/``; tests/examples are
  exempt by scope.  Sanctioned escape hatch for shape-only uses: a
  ``# analysis: shape-only`` comment on the call line or the line above.
* ``spec-strings`` — every literal component-spec string (spec-valued
  keyword arguments, dataclass field defaults, ``axes={...}`` sweep dicts,
  ``resolve``/``make_env``/``Spec.parse`` call sites) must ``Spec.parse``
  and name a registered component whose factory accepts the given kwargs.
  Covers README/DESIGN code fences, so doc rot fails CI.
* ``pallas-location`` — ``pallas_call`` only under ``repro/kernels/``.
* ``numpy-traced`` — no host ``numpy`` calls inside nested functions of
  the hot modules (those closures are traced; ``np.*`` on a tracer either
  crashes or silently constant-folds).  Escape hatch:
  ``# analysis: host-side``.
* ``tracked-smoke-file`` — no ``benchmarks/*_smoke.json`` committed to
  git (smoke outputs are per-run CI artifacts, not baselines).
* ``deep-import`` — examples must not deep-import names the public
  surface (``repro/__init__._EXPORTS``) already re-exports: examples are
  the API's showroom, and ``from repro.core.engine import Experiment``
  there teaches users a private path.  Escape hatch:
  ``# analysis: deep-import``.
"""

from __future__ import annotations

import ast
import dataclasses
import re
import subprocess
from pathlib import Path
from typing import Iterable, Optional

from repro.analysis.findings import Finding

# keyword/field name -> registry namespaces it may resolve in
SPEC_KWARGS = {
    "attack": ("attack", "fed_attack"),
    "aggregator": ("aggregator", "fed_aggregator"),
    "agreement": ("agreement",),
    "estimator": ("estimator",),
    "optimizer": ("optimizer",),
    "topology": ("topology",),
    "policy": ("policy",),
    "env": ("env",),
    "algo": ("algo",),
}

# call name -> namespace of its literal first spec argument
SPEC_CALLS = {
    "make_env": "env",
    "resolve_topology": "topology",
}


@dataclasses.dataclass
class LintConfig:
    root: Path
    lib_prefixes: tuple = ("src/",)
    spec_prefixes: tuple = ("src/", "examples/", "benchmarks/")
    doc_files: tuple = ("README.md", "DESIGN.md")
    kernel_prefix: str = "src/repro/kernels/"
    hot_prefixes: tuple = ("src/repro/core/", "src/repro/rl/",
                           "src/repro/distributed/")
    # the analyzer's own rule tables are spec-shaped data, not spec sites
    spec_exclude: tuple = ("src/repro/analysis/",)
    smoke_patterns: tuple = ("benchmarks/*_smoke.json", "*_smoke.json")


@dataclasses.dataclass
class FileCtx:
    rel: str                 # repo-relative posix path ("README.md#3" for
    tree: ast.AST            # the 3rd code fence)
    lines: list              # raw source lines (1-indexed via lineno-1)
    line_offset: int = 0     # fence offset into the containing document
    is_doc_fence: bool = False

    def line(self, node) -> int:
        return node.lineno + self.line_offset

    def has_hatch(self, node, tag: str) -> bool:
        marker = f"# analysis: {tag}"
        for ln in (node.lineno - 1, node.lineno - 2):
            if 0 <= ln < len(self.lines) and marker in self.lines[ln]:
                return True
        return False


class Rule:
    name = "rule"

    def wants(self, ctx: FileCtx, cfg: LintConfig) -> bool:
        raise NotImplementedError

    def visit(self, ctx: FileCtx, cfg: LintConfig) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileCtx, node, message: str) -> Finding:
        rel = ctx.rel.split("#")[0]
        return Finding("lint", self.name, rel, ctx.line(node), message)


def _starts_with(rel: str, prefixes) -> bool:
    return any(rel.startswith(p) for p in prefixes)


# ---------------------------------------------------------------------------
# literal-prng-key
# ---------------------------------------------------------------------------


def _attr_chain(node) -> list:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_prng_ctor(func) -> bool:
    chain = _attr_chain(func)
    if not chain:
        return False
    if chain[-1] == "PRNGKey":
        return True
    return chain[-1] == "key" and "random" in chain[:-1]


class LiteralPRNGKey(Rule):
    name = "literal-prng-key"

    def wants(self, ctx, cfg):
        return not ctx.is_doc_fence and _starts_with(ctx.rel,
                                                     cfg.lib_prefixes)

    def visit(self, ctx, cfg):
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and _is_prng_ctor(node.func) and node.args):
                continue
            if not isinstance(node.args[0], ast.Constant):
                continue
            if ctx.has_hatch(node, "shape-only"):
                continue
            yield self.finding(
                ctx, node,
                "literal PRNG key in library code — thread an explicit "
                "key (engine.seed_keys / caller-provided key=), or mark a "
                "shape-only use with '# analysis: shape-only'")


# ---------------------------------------------------------------------------
# spec-strings
# ---------------------------------------------------------------------------


def _validate_spec(text: str, namespaces) -> Optional[str]:
    """Parse + resolve a spec string; returns an error message or None."""
    from repro.core.registry import REGISTRY, Spec, SpecError
    try:
        spec = Spec.parse(text)
    except SpecError as e:
        return str(e)
    if namespaces is None:          # parse-only site (Spec.parse/Spec.of)
        return None
    import inspect
    errors = []
    for ns in namespaces:
        try:
            factory = REGISTRY._factory(ns, spec.name)
        except KeyError:
            errors.append(f"not registered in {ns!r}")
            continue
        params = inspect.signature(factory).parameters
        var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                     for p in params.values())
        bad = [k for k, _ in spec.kwargs if not var_kw and k not in params]
        if bad:
            errors.append(f"{ns}/{spec.name} does not accept kwarg(s) "
                          f"{bad}")
            continue
        for k, v in spec.kwargs:
            if isinstance(v, Spec):
                err = _validate_spec(v.canonical(), (ns,))
                if err:
                    errors.append(err)
                    break
        else:
            return None
        continue
    return "; ".join(errors) or None


def _literal_specs(value) -> list:
    """(text, node) pairs for a literal spec value: a string constant or a
    tuple/list of them (sweep axes)."""
    out = []
    if isinstance(value, ast.Constant) and isinstance(value.value, str):
        out.append((value.value, value))
    elif isinstance(value, (ast.Tuple, ast.List)):
        for el in value.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append((el.value, el))
    return out


class SpecStrings(Rule):
    name = "spec-strings"

    def wants(self, ctx, cfg):
        if _starts_with(ctx.rel, cfg.spec_exclude):
            return False
        return ctx.is_doc_fence or _starts_with(ctx.rel, cfg.spec_prefixes)

    def _sites(self, ctx):
        """(text, node, namespaces) for every literal spec site.  A
        ``# analysis: not-a-spec`` comment on (or above) a dict or call
        exempts spec-shaped data that is not a component spec."""
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Call, ast.Dict, ast.AnnAssign)) \
                    and ctx.has_hatch(node, "not-a-spec"):
                continue
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                tail = chain[-1] if chain else None
                for kw in node.keywords:
                    if kw.arg in SPEC_KWARGS:
                        for text, n in _literal_specs(kw.value):
                            yield text, n, SPEC_KWARGS[kw.arg]
                if tail == "resolve" and len(node.args) >= 2 \
                        and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    for text, n in _literal_specs(node.args[1]):
                        yield text, n, (node.args[0].value,)
                elif tail in SPEC_CALLS and node.args:
                    for text, n in _literal_specs(node.args[0]):
                        yield text, n, (SPEC_CALLS[tail],)
                elif tail in ("parse", "of") and len(chain) >= 2 \
                        and chain[-2] == "Spec" and node.args:
                    for text, n in _literal_specs(node.args[0]):
                        yield text, n, None
            elif isinstance(node, ast.Dict):
                for k, v in zip(node.keys, node.values):
                    if isinstance(k, ast.Constant) \
                            and k.value in SPEC_KWARGS:
                        for text, n in _literal_specs(v):
                            yield text, n, SPEC_KWARGS[k.value]
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name) \
                    and node.target.id in SPEC_KWARGS \
                    and node.value is not None:
                for text, n in _literal_specs(node.value):
                    yield text, n, SPEC_KWARGS[node.target.id]

    def visit(self, ctx, cfg):
        seen = set()
        for text, node, namespaces in self._sites(ctx):
            key = (text, ctx.line(node))
            if key in seen:
                continue
            seen.add(key)
            err = _validate_spec(text, namespaces)
            if err:
                yield self.finding(
                    ctx, node, f"spec string {text!r} does not resolve: "
                               f"{err}")


# ---------------------------------------------------------------------------
# pallas-location
# ---------------------------------------------------------------------------


class PallasLocation(Rule):
    name = "pallas-location"

    def wants(self, ctx, cfg):
        return (not ctx.is_doc_fence
                and _starts_with(ctx.rel, cfg.spec_prefixes)
                and not ctx.rel.startswith(cfg.kernel_prefix))

    def visit(self, ctx, cfg):
        for node in ast.walk(ctx.tree):
            chain = _attr_chain(node.func) if isinstance(node, ast.Call) \
                else _attr_chain(node) if isinstance(node, ast.Attribute) \
                else []
            if chain and chain[-1] == "pallas_call":
                yield self.finding(
                    ctx, node,
                    "pallas_call outside repro/kernels/ — kernels live "
                    "behind the dispatch layer (DESIGN.md §6)")
                return      # one per file is enough


# ---------------------------------------------------------------------------
# numpy-traced
# ---------------------------------------------------------------------------


class NumpyInTracedScope(Rule):
    name = "numpy-traced"

    def wants(self, ctx, cfg):
        return not ctx.is_doc_fence and _starts_with(ctx.rel,
                                                     cfg.hot_prefixes)

    @staticmethod
    def _numpy_aliases(tree) -> set:
        aliases = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        aliases.add(a.asname or "numpy")
        return aliases

    def visit(self, ctx, cfg):
        aliases = self._numpy_aliases(ctx.tree)
        if not aliases:
            return
        # nested function bodies are the traced closures
        for outer in ast.walk(ctx.tree):
            if not isinstance(outer, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            for inner in ast.walk(outer):
                if inner is outer or not isinstance(
                        inner, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                    continue
                for node in ast.walk(inner):
                    if isinstance(node, ast.Call):
                        chain = _attr_chain(node.func)
                        if len(chain) >= 2 and chain[0] in aliases \
                                and not ctx.has_hatch(node, "host-side"):
                            yield self.finding(
                                ctx, node,
                                f"host numpy call "
                                f"({'.'.join(chain)}) inside a nested "
                                f"(traced) function of a hot module — "
                                f"use jnp, or mark trace-time constant "
                                f"work with '# analysis: host-side'")


# ---------------------------------------------------------------------------
# deep-import
# ---------------------------------------------------------------------------


class DeepImport(Rule):
    name = "deep-import"

    def wants(self, ctx, cfg):
        return not ctx.is_doc_fence and ctx.rel.startswith("examples/")

    @staticmethod
    def _public_names() -> dict:
        """name -> defining submodule, from the public surface itself (so
        this rule can never drift from ``repro/__init__``)."""
        import repro
        return dict(repro._EXPORTS)

    def visit(self, ctx, cfg):
        public = self._public_names()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ImportFrom) or node.level:
                continue
            mod = node.module or ""
            if not mod.startswith("repro."):
                continue
            if ctx.has_hatch(node, "deep-import"):
                continue
            covered = [a.name for a in node.names if a.name in public]
            if covered:
                yield self.finding(
                    ctx, node,
                    f"deep import from {mod!r} of public name(s) "
                    f"{covered} — examples should use the public surface "
                    f"(from repro import {', '.join(covered)}); mark a "
                    f"deliberate internal demo with "
                    f"'# analysis: deep-import'")


# ---------------------------------------------------------------------------
# tracked-smoke-file (repo-level, no AST)
# ---------------------------------------------------------------------------


def check_tracked_smoke(cfg: LintConfig) -> list:
    try:
        out = subprocess.run(
            ["git", "ls-files", "--", *cfg.smoke_patterns],
            cwd=cfg.root, capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        return []
    if out.returncode != 0:
        return []
    return [
        Finding("lint", "tracked-smoke-file", p, 0,
                "smoke benchmark output is tracked by git — smoke runs "
                "are per-run CI artifacts, only full BENCH_*.json "
                "baselines are committed")
        for p in out.stdout.split() if p
    ]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

RULES = (LiteralPRNGKey(), SpecStrings(), PallasLocation(),
         NumpyInTracedScope(), DeepImport())

_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def _doc_fences(rel: str, text: str):
    """Yield (rel#i, fence_source, line_offset) for ```python fences."""
    lines = text.splitlines()
    i, n, count = 0, len(lines), 0
    while i < n:
        m = _FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 1
            j = start
            while j < n and not lines[j].startswith("```"):
                j += 1
            count += 1
            yield f"{rel}#{count}", "\n".join(lines[start:j]), start
            i = j + 1
        else:
            i += 1


def _contexts(cfg: LintConfig):
    prefixes = set(cfg.lib_prefixes) | set(cfg.spec_prefixes) \
        | set(cfg.hot_prefixes)
    seen = set()
    for prefix in sorted(prefixes):
        base = cfg.root / prefix
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(cfg.root).as_posix()
            if rel in seen:
                continue
            seen.add(rel)
            text = path.read_text()
            try:
                tree = ast.parse(text)
            except SyntaxError:
                continue        # not this tool's job
            yield FileCtx(rel, tree, text.splitlines())
    for doc in cfg.doc_files:
        path = cfg.root / doc
        if not path.is_file():
            continue
        text = path.read_text()
        for rel, src, offset in _doc_fences(doc, text):
            try:
                tree = ast.parse(src)
            except SyntaxError:
                continue        # illustrative snippet, not runnable code
            yield FileCtx(rel, tree, src.splitlines(), line_offset=offset,
                          is_doc_fence=True)


def repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def run(root: Optional[Path] = None,
        config: Optional[LintConfig] = None) -> list:
    cfg = config or LintConfig(root=Path(root) if root else repo_root())
    findings = []
    for ctx in _contexts(cfg):
        for rule in RULES:
            if rule.wants(ctx, cfg):
                findings.extend(rule.visit(ctx, cfg))
    findings.extend(check_tracked_smoke(cfg))
    return findings
