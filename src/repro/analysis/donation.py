"""Donation audit: every ``donate_argnums`` site must fully alias.

A donated buffer that XLA cannot reuse for an output is pure dead weight:
the caller's arrays are invalidated, a "donated buffers were not usable"
warning fires on device backends, and no memory is saved.  On CPU the
engine's :func:`repro.core.engine.donate_args` disables donation by
policy, so nothing in the regular test suite would ever catch a
non-aliasable donation shipped to TPU.  This pass therefore re-compiles
each donation site with its donation *forced* (bypassing the CPU guard)
and checks, per site:

* no "donated buffers were not usable" warning during lowering/compile;
* ``memory_analysis().alias_size_in_bytes`` equals the byte size of the
  donated arguments — every donated byte is reused for an output;
* the declared argnums still match the site's source (drift check), so
  this registry cannot silently rot.

Sites: the fused algo loops (``fused_decbyzpg``/``fused_byzpg``), the
fused federated window (``launch/train.py``), the sharded federated step
(``make_fed_step``), the serving decode step (``make_serve_fns``) and the
continuous-batching engine's tick/insert programs
(``repro.serving.engine``).
"""

from __future__ import annotations

import dataclasses
import math
import re
import warnings
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

_UNUSABLE = "donated buffers were not usable"


@dataclasses.dataclass(frozen=True)
class Site:
    """One donation site: where it lives, which argnums it donates, and a
    builder returning ``(fn, example_args)`` for a forced-donation
    compile."""
    name: str
    path: str                    # repo-relative source file
    donate_argnums: tuple
    build: Callable              # () -> (fn, args tuple)
    # regex that must match the site's source if the argnums still agree
    source_pattern: str


def _bytes_of(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(
        math.prod(l.shape) * jnp.dtype(l.dtype).itemsize for l in leaves)


def _compile_with_donation(fn, args, donate_argnums):
    jitted = jax.jit(fn, donate_argnums=donate_argnums)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = jitted.lower(*args).compile()
    msgs = [str(w.message) for w in caught if _UNUSABLE in str(w.message)]
    return compiled, msgs


def check_site(site: Site, root: Optional[Path] = None) -> list:
    from repro.analysis.lint import repo_root
    root = root or repo_root()
    findings = []

    def bad(rule, msg):
        findings.append(Finding("donation", rule, site.path, 0,
                                f"[{site.name}] {msg}"))

    src_path = root / site.path
    if src_path.is_file():
        if not re.search(site.source_pattern, src_path.read_text()):
            bad("site-drift",
                f"declared donate_argnums {site.donate_argnums} no longer "
                f"match the source (pattern {site.source_pattern!r} not "
                f"found) — update the repro.analysis.donation site "
                f"registry")
            return findings
    fn, args = site.build()
    compiled, unusable = _compile_with_donation(fn, args,
                                                site.donate_argnums)
    if unusable:
        bad("unusable-donation",
            f"XLA could not reuse every donated buffer: "
            f"{unusable[0][:300]}")
    ma = compiled.memory_analysis()
    donated = sum(_bytes_of(args[i]) for i in site.donate_argnums)
    aliased = getattr(ma, "alias_size_in_bytes", None)
    if aliased is not None and aliased < donated:
        bad("partial-alias",
            f"only {aliased} of {donated} donated bytes alias an output "
            f"— non-aliasable donated args are dead weight; donate only "
            f"the carries that come back out")
    return findings


# ---------------------------------------------------------------------------
# Site registry
# ---------------------------------------------------------------------------


def _algo_site(algo: str):
    from repro.core import engine
    from repro.rl.envs import make_env
    env = make_env("cartpole(horizon=12)")
    if algo == "decbyzpg":
        from repro.core.decbyzpg import (DecByzPGConfig,
                                         build_decbyzpg_loop,
                                         init_decbyzpg_carry)
        cfg = DecByzPGConfig(K=3, n_byz=1, N=3, B=2, kappa=1,
                             agreement="gda", hidden=(8,))
        build, init = build_decbyzpg_loop, init_decbyzpg_carry
    else:
        from repro.core.byzpg import (ByzPGConfig, build_byzpg_loop,
                                      init_byzpg_carry)
        cfg = ByzPGConfig(K=3, n_byz=1, N=3, B=2, hidden=(8,))
        build, init = build_byzpg_loop, init_byzpg_carry
    T = 2
    ks = engine.seed_keys(0)
    carry = init(env, cfg, ks.init)
    loop = build(env, cfg, T)
    return loop, (*carry, jax.random.split(ks.loop, T), ks.coin)


def _fed_shapes():
    from repro.configs import get_config, reduced
    from repro.distributed.fed_trainer import FedConfig, init_fed_state
    cfg = reduced(get_config("llama3_2_1b"))
    fed = FedConfig(aggregator="rfa", kappa=1, n_byz=0)
    K = 2
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state = jax.eval_shape(lambda k: init_fed_state(cfg, fed, K, k), key)
    batch = {"tokens": jax.ShapeDtypeStruct((K, 2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((K, 2, 16), jnp.int32)}
    mask = jax.ShapeDtypeStruct((K,), jnp.bool_)
    return cfg, fed, K, key, state, batch, mask


def _fed_window_site():
    from repro.distributed.fed_trainer import fed_train_window
    cfg, fed, K, key, state, batch, mask = _fed_shapes()
    W = 2
    batches = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((W,) + s.shape, s.dtype), batch)
    ts = jax.ShapeDtypeStruct((W,), jnp.int32)
    fn = lambda s, b, m, t, k: fed_train_window(cfg, fed, s, b, m, t, k)
    return fn, (state, batches, mask, ts, key)


def _fed_step_site():
    from repro.distributed.fed_trainer import make_fed_step
    cfg, fed, K, key, state, batch, mask = _fed_shapes()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step, state_shape, batch_shape, _ = make_fed_step(
        cfg, fed, mesh, large=True, per_agent_batch=2, seq_len=16,
        key=key)
    K = jax.tree.leaves(state_shape.params)[0].shape[0]
    mask = jax.ShapeDtypeStruct((K,), jnp.bool_)
    # make_fed_step already jits with donate_argnums=(0,); rebuild the
    # same lambda so the audit controls (and forces) the donation.  The
    # large=False (PAGE) variant is the one that reads every FedState
    # leaf — under large=True XLA dead-code-eliminates prev_params/v, and
    # a DCE'd input can never alias, so full aliasing is only a meaningful
    # contract on the full-read program.
    from repro.distributed.fed_trainer import fed_train_step
    fn = lambda s, b, m, k: fed_train_step(cfg, fed, s, b, m, k,
                                           large=False)
    return fn, (state_shape, batch_shape, mask, key)


def _serving_site():
    from repro.configs import get_config, reduced
    from repro.distributed.serving import make_serve_fns
    from repro.models.model import decode_step
    cfg = reduced(get_config("llama3_2_1b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fns = make_serve_fns(cfg, mesh, batch=2, seq_len=32, key=key)
    tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    fn = lambda p, t, c: decode_step(cfg, p, t, c)
    return fn, (fns.params_shape, tok, fns.cache_shape)


def _slot_engine():
    from repro.configs import get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import DecodeEngine
    cfg = reduced(get_config("llama3_2_1b"))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(lambda k: init_params(cfg, k), key)
    engine = DecodeEngine(cfg, None, slots=2, max_new=4, max_prompt=4)
    state = jax.eval_shape(engine.init_state)
    return cfg, engine, params, state


def _serving_tick_site():
    cfg, engine, params, state = _slot_engine()
    return engine._tick_impl, (params, state)


def _serving_insert_site():
    from repro.models.model import init_cache
    cfg, engine, params, state = _slot_engine()
    row = jax.eval_shape(lambda: init_cache(cfg, 1, engine.cache_len))
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return engine._insert_impl, (state, i32, row, i32, i32, i32)


def sites() -> list:
    return [
        Site("fused_decbyzpg", "src/repro/core/decbyzpg.py", (0,),
             lambda: _algo_site("decbyzpg"),
             r"donate_argnums=engine\.donate_args\(0\)"),
        Site("fused_byzpg", "src/repro/core/byzpg.py", (0,),
             lambda: _algo_site("byzpg"),
             r"donate_argnums=engine\.donate_args\(0\)"),
        Site("fed_train_window", "src/repro/launch/train.py", (0,),
             _fed_window_site,
             r"donate_argnums=engine\.donate_args\(0\)"),
        Site("make_fed_step", "src/repro/distributed/fed_trainer.py",
             (0,), _fed_step_site, r"donate_argnums=\(0,\)"),
        Site("serving_decode", "src/repro/distributed/serving.py", (2,),
             _serving_site, r"donate_argnums=\(2,\)"),
        Site("serving_tick", "src/repro/serving/engine.py", (1,),
             _serving_tick_site, r"donate_argnums=donate_args\(1\)"),
        Site("serving_insert", "src/repro/serving/engine.py", (0,),
             _serving_insert_site, r"donate_argnums=donate_args\(0\)"),
    ]


def run(root: Optional[Path] = None) -> list:
    findings = []
    for site in sites():
        findings.extend(check_site(site, root))
    return findings
