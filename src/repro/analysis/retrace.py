"""Recompile audit: one compile per (algo, static signature), never more.

Two layers:

* :func:`audit_static` — pure config hygiene per registered algorithm:
  the default config must construct, its :func:`engine.static_key` must
  hash (unhashable field ⇒ silent cache miss every call ⇒ retrace), two
  equal configs must produce equal static keys (an ``object()`` default
  would make every instance its own cache key), the seed must not reach
  the static key, and — the lane-batching contract — changing a
  ``traced_fields`` scalar must leave :func:`engine.lane_split`'s static
  representative (and its hash) unchanged.

* :func:`audit_compiles` — dynamic compile counting over a real
  lane-batched grid.  A grid with two static attack shapes × a traced
  eta sweep must compile exactly ``len(lane groups)`` programs
  (``engine.compile_count`` delta), and re-running the *same* static
  grid with different traced values and different seeds must add zero
  cache entries and emit zero ``jax.log_compiles`` records — sweeping a
  traced scalar or a seed must never reach the compile cache key.

Used by ``python -m repro.analysis`` and ``tests/test_analysis_retrace.py``;
the CLI entry is the CI compile-count gate.
"""

from __future__ import annotations

import dataclasses
import inspect
import logging
import re
from typing import Optional

import jax

from repro.analysis.findings import Finding

_COMPILING_RE = re.compile(r"^Compiling ([\w<>-]+) ")


class CompileLog:
    """Context manager capturing XLA "Compiling <name> ..." records (via
    ``jax.log_compiles``) on the jax logger tree."""

    def __init__(self):
        self.messages: list = []

    def compiles(self) -> list:
        """Names of compiled programs, in order."""
        out = []
        for m in self.messages:
            match = _COMPILING_RE.match(m)
            if match:
                out.append(match.group(1))
        return out

    def __enter__(self):
        outer = self

        class _Handler(logging.Handler):
            def emit(self, record):
                outer.messages.append(record.getMessage())

        self._handler = _Handler(level=logging.DEBUG)
        self._logger = logging.getLogger("jax")
        self._logger.addHandler(self._handler)
        self._log_compiles = jax.log_compiles()
        self._log_compiles.__enter__()
        return self

    def __exit__(self, *exc):
        self._log_compiles.__exit__(*exc)
        self._logger.removeHandler(self._handler)
        return False


# ---------------------------------------------------------------------------
# Static audit
# ---------------------------------------------------------------------------


def _anchor(cls) -> tuple:
    try:
        path = inspect.getsourcefile(cls) or "<unknown>"
        line = inspect.getsourcelines(cls)[1]
    except (OSError, TypeError):
        path, line = "<unknown>", 0
    return path, line


def audit_static_config(algo: str, config_cls, traced_fields) -> list:
    """Cache-key hygiene findings for one algorithm config class."""
    from repro.core import engine
    path, line = _anchor(config_cls)
    findings = []

    def bad(rule, msg):
        findings.append(Finding("retrace", rule, path, line,
                                f"[{algo}] {msg}"))

    try:
        cfg = config_cls()
    except Exception as e:
        bad("default-config", f"{config_cls.__name__}() must construct "
            f"(the analysis passes and grid defaults rely on it): {e}")
        return findings
    try:
        h1 = hash(engine.static_key(cfg))
    except TypeError as e:
        bad("unhashable-static", f"static_key(cfg) is unhashable — every "
            f"compiled-loop cache lookup would miss and retrace: {e}")
        return findings
    cfg2 = config_cls()
    if engine.static_key(cfg) != engine.static_key(cfg2) \
            or h1 != hash(engine.static_key(cfg2)):
        bad("unstable-static-key",
            "two identically-constructed configs produce different static "
            "keys — per-instance state (e.g. an object() default) defeats "
            "the compile cache")
        return findings
    if engine.static_key(dataclasses.replace(cfg, seed=cfg.seed + 17)) \
            != engine.static_key(cfg):
        bad("seed-in-static-key",
            "the seed reaches static_key — every seed would compile its "
            "own program (seeds are data, not program)")

    fields = {f.name for f in dataclasses.fields(cfg)}
    present = []
    for name in traced_fields:
        if hasattr(cfg, name):
            present.append(name)
        else:
            bad("traced-field-missing",
                f"traced field {name!r} is neither a dataclass field nor "
                f"a derived property — lane_split would crash on it")
    traced_fields = tuple(present)
    base_static, base_names, _ = engine.lane_split(cfg, traced_fields)
    for name in traced_fields:
        field = name if name in fields \
            else ("p" if name == "switch_p" and "p" in fields else None)
        if field is None:
            continue
        old = getattr(cfg, field)
        new = 0.375 if not isinstance(old, float) else old + 0.125
        swept = dataclasses.replace(cfg, **{field: new})
        static, names, _ = engine.lane_split(swept, traced_fields)
        if static != base_static or hash(static) != hash(base_static) \
                or names != base_names:
            bad("traced-leaks-into-static",
                f"sweeping traced field {name!r} (via {field!r}) changes "
                f"the lane-group static representative — the sweep would "
                f"compile one program per value instead of lane-batching")
    return findings


def audit_static() -> list:
    from repro.core.registry import REGISTRY, resolve
    findings = []
    for algo in REGISTRY.names("algo"):
        a = resolve("algo", algo)
        findings.extend(
            audit_static_config(algo, a.config_cls, a.traced_fields))
    return findings


# ---------------------------------------------------------------------------
# Dynamic audit
# ---------------------------------------------------------------------------


def _grid(etas, seeds):
    from repro.core import engine
    return engine.ScenarioGrid(
        seeds=seeds, axes={"eta": tuple(etas),
                           "attack": ("none", "sign_flip")})


_BASE = dict(K=3, n_byz=1, N=3, B=2, hidden=(8,))


def _expected_groups(env, grid, algo="decbyzpg", **base) -> int:
    from repro.core import engine
    from repro.core.registry import resolve
    a = resolve("algo", algo)
    fields = {f.name for f in dataclasses.fields(a.config_cls)}
    groups = set()
    for scn in grid.scenarios():
        assign = {k: v for k, v in scn._asdict().items() if k in fields}
        cfg = a.config_cls(**{**base, **assign})
        static_cfg, names, _ = engine.lane_split(cfg, a.traced_fields)
        groups.add((static_cfg, names))
    return len(groups)


def audit_compiles(T: int = 2) -> list:
    """Run a two-group lane grid twice and assert the compile counts:
    first run compiles exactly the lane-group count, a re-sweep with new
    traced values and seeds compiles nothing."""
    from repro.core import engine
    from repro.rl.envs import make_env
    env = make_env("cartpole(horizon=12)")
    findings = []

    def bad(rule, msg):
        findings.append(Finding(
            "retrace", rule,
            inspect.getsourcefile(engine.lane_batch_loop) or "<unknown>",
            0, msg))

    grid_a = _grid((5e-3, 1e-2), seeds=(0, 1))
    expected = _expected_groups(env, grid_a, **_BASE)
    c0 = engine.compile_count()
    engine.run_grid(env, grid_a, T, algo="decbyzpg", **_BASE)
    delta = engine.compile_count() - c0
    if delta != expected:
        bad("compile-count",
            f"lane-grouped grid compiled {delta} programs, expected "
            f"{expected} (one per (algo, static_key, traced-names) "
            f"group)")

    # same static signatures and batch shape, new traced values + new
    # seeds: nothing may compile — neither in the engine cache nor in XLA
    # (the lane/seed counts stay fixed; row count is legitimately static)
    grid_b = _grid((2e-2, 3e-2), seeds=(2, 3))
    c1 = engine.compile_count()
    with CompileLog() as log:
        engine.run_grid(env, grid_b, T, algo="decbyzpg", **_BASE)
    delta_b = engine.compile_count() - c1
    if delta_b != 0:
        bad("traced-retrace",
            f"re-running the same static grid with new traced values and "
            f"seeds added {delta_b} cache entries — a traced_fields value "
            f"or the seed leaks into the compiled-loop cache key")
    recompiled = log.compiles()
    if recompiled:
        bad("xla-recompile",
            f"re-running the same static grid with new traced values and "
            f"seeds triggered XLA compiles: {recompiled[:5]} — a traced "
            f"operand is reaching jit as a static argument")
    return findings


def run(dynamic: bool = True) -> list:
    findings = audit_static()
    if dynamic:
        findings.extend(audit_compiles())
    return findings
