"""JAX-aware static analysis for the repro codebase.

Two layers (DESIGN.md §7):

* **Program passes** walk the *real* compiled-program builders:
  :mod:`repro.analysis.keycheck` (PRNG-key discipline in jaxprs),
  :mod:`repro.analysis.retrace` (compile-count / static-key hygiene),
  :mod:`repro.analysis.donation` (donated-buffer aliasing),
  :mod:`repro.analysis.memcheck` (declarative per-program memory contracts).
* **AST lint** (:mod:`repro.analysis.lint`) enforces repo conventions on
  source text: no literal ``PRNGKey`` in library code, spec strings must
  resolve against the registry (including README/DESIGN code fences),
  ``pallas_call`` only under ``repro/kernels/``, no host ``numpy`` on traced
  values in hot modules, no tracked smoke-benchmark artifacts.

Run everything with ``python -m repro.analysis`` (exit 1 on any finding),
or individual passes with ``--passes``.  Each pass is also exercised by a
tier-1 pytest suite (``tests/test_analysis_*.py``) with deliberately broken
fixtures proving the pass actually fires.
"""

from repro.analysis.findings import Finding, render

__all__ = ["Finding", "render"]
