"""PRNG-key discipline over the real compiled-program builders.

The paper's variance-reduction and robustness arguments assume the K
per-agent trajectory batches are sampled i.i.d. — a reused PRNG key across
agents (or across the attack/aggregation/agreement draws of one round)
silently correlates the streams without failing any numeric test.  This
pass traces the actual fused loop builders (``build_decbyzpg_loop``,
``build_byzpg_loop``, ``lane_batch_loop``, ``fed_train_step[_flat]``,
``fed_train_window``) and walks the resulting ClosedJaxpr with a key-identity
dataflow analysis:

* every key-typed input / ``random_seed`` output gets a fresh identity;
* ``random_wrap``/``random_unwrap``/reshapes propagate the identity;
* static slices of a split batch derive *distinct* sub-stream identities
  (so ``unwrap → slice → squeeze → wrap`` subkey extraction is clean);
* ``random_split``/``random_fold_in`` consume the parent and produce fresh
  children; ``random_bits`` (the sink under ``normal``/``bernoulli``/...)
  is a *sample* of its operand.

Contracts checked per key identity:

* ``key-reuse`` — sampled by ≥2 primitives that can both execute
  (events in sibling ``lax.cond`` branches are mutually exclusive);
* ``sample-then-derive`` — sampled *and* split/folded (children of a
  sampled key correlate with the sample);
* ``double-split`` — split twice (identical child streams);
* ``scan-invariant-sample`` — sampled inside a ``scan``/``while`` body
  while originating outside the loop (same draw every iteration; fold in
  the loop index first);
* ``per-agent-fanout`` — the algo loops must contain a K-wide
  ``random_split`` feeding the per-agent sampling streams.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

try:
    from jax._src.core import Literal as _Literal
except Exception:                                   # pragma: no cover
    _Literal = type(None)

SAMPLE = "sample"
SPLIT = "split"
FOLD = "fold_in"

# identity-preserving prims: same key stream, new layout
_PASSTHROUGH = {
    "random_wrap", "random_unwrap", "reshape", "squeeze", "expand_dims",
    "broadcast_in_dim", "transpose", "copy", "convert_element_type",
    "stop_gradient", "device_put",
}
# static reslicing of a key batch: derived sub-stream, distinct per params
_SLICING = {"slice", "gather", "dynamic_slice"}


class _Key:
    """One PRNG stream identity flowing through a jaxpr."""

    __slots__ = ("label", "depth")

    def __init__(self, label: str, depth: int):
        self.label = label
        self.depth = depth      # number of enclosing loop bodies at creation


@dataclasses.dataclass(frozen=True)
class _Event:
    kind: str
    path: str
    line: int
    ctx: tuple          # ((cond_uid, branch_idx), ...) enclosing cond path
    loop_invariant: bool


def _conflicts(a: _Event, b: _Event) -> bool:
    """Can both events execute in one evaluation?  Events diverging at a
    common ``lax.cond`` into different branches are mutually exclusive."""
    for x, y in zip(a.ctx, b.ctx):
        if x != y:
            return not (x[0] == y[0] and x[1] != y[1])
    return True


def _any_conflicting_pair(evs_a, evs_b) -> Optional[tuple]:
    for a in evs_a:
        for b in evs_b:
            if a is not b and _conflicts(a, b):
                return a, b
    return None


def _is_key_aval(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return False
    try:
        if jnp.issubdtype(dtype, jax.dtypes.prng_key):
            return True
    except Exception:
        pass
    shape = getattr(aval, "shape", ())
    return dtype == jnp.uint32 and len(shape) >= 1 and shape[-1] == 2


def _src(eqn) -> tuple:
    try:
        from jax._src import source_info_util
        fr = source_info_util.user_frame(eqn.source_info)
        if fr is not None:
            return fr.file_name, fr.start_line
    except Exception:
        pass
    return "<unknown>", 0


class _Walker:
    """Key-identity dataflow over a ClosedJaxpr (recursing into pjit,
    scan, while, cond and custom-call sub-jaxprs)."""

    def __init__(self):
        self.events: dict = {}          # _Key -> list[_Event]
        self.split_fanouts: list = []   # leading dim of each split output

    # -- bookkeeping -------------------------------------------------------

    def _record(self, env, var, kind, eqn, ctx, depth):
        kid = env.get(var)
        if kid is None:
            return
        path, line = _src(eqn)
        self.events.setdefault(kid, []).append(
            _Event(kind, path, line, ctx, depth > kid.depth))

    @staticmethod
    def _get(env, v):
        return None if isinstance(v, _Literal) else env.get(v)

    def _fresh_outs(self, env, eqn, depth, label):
        for ov in eqn.outvars:
            if _is_key_aval(ov.aval):
                env[ov] = _Key(label, depth)

    # -- recursion helpers -------------------------------------------------

    def _enter(self, sub_jaxpr, operands, consts, env, ctx, depth,
               outer_env_ids=True):
        """Build a child env binding sub-jaxpr invars/constvars to the
        operand identities (fresh for key-typed binders with no tracked
        operand)."""
        sub_env = {}
        for cv, cval in zip(sub_jaxpr.constvars, consts):
            if _is_key_aval(cv.aval):
                sub_env[cv] = _Key("const", depth)
        for bv, op in zip(sub_jaxpr.invars, operands):
            kid = self._get(env, op) if outer_env_ids else None
            if kid is not None:
                sub_env[bv] = kid
            elif _is_key_aval(bv.aval):
                sub_env[bv] = _Key("binder", depth)
        return sub_env

    def _propagate_outs(self, sub_jaxpr, sub_env, eqn, env):
        for ov, sv in zip(eqn.outvars, sub_jaxpr.outvars):
            kid = self._get(sub_env, sv)
            if kid is not None:
                env[ov] = kid

    # -- the walk ----------------------------------------------------------

    def walk(self, closed, env=None, ctx=(), depth=0):
        jaxpr = getattr(closed, "jaxpr", closed)
        consts = getattr(closed, "consts", ())
        if env is None:
            env = {}
            for cv in jaxpr.constvars:
                if _is_key_aval(cv.aval):
                    env[cv] = _Key("const", depth)
            for iv in jaxpr.invars:
                if _is_key_aval(iv.aval):
                    env[iv] = _Key("input", depth)
        for eqn in jaxpr.eqns:
            self._eqn(jaxpr, eqn, env, ctx, depth)
        return env

    def _eqn(self, jaxpr, eqn, env, ctx, depth):
        name = eqn.primitive.name

        if name == "random_seed":
            self._fresh_outs(env, eqn, depth, "seed")
        elif name in _PASSTHROUGH:
            kid = self._get(env, eqn.invars[0]) if eqn.invars else None
            if kid is not None:
                for ov in eqn.outvars:
                    env[ov] = kid
        elif name in _SLICING:
            kid = self._get(env, eqn.invars[0])
            if kid is not None:
                # static slice params make a reproducible sub-stream id;
                # traced indices (dynamic_slice/gather operands) make each
                # eqn its own stream (can't distinguish runtime indices)
                label = f"{name}:{id(eqn)}"
                for ov in eqn.outvars:
                    env[ov] = _Key(label, kid.depth)
        elif name == "random_split":
            self._record(env, eqn.invars[0], SPLIT, eqn, ctx, depth)
            out_shape = getattr(eqn.outvars[0].aval, "shape", ())
            if out_shape:
                self.split_fanouts.append(out_shape[0])
            self._fresh_outs(env, eqn, depth, "split-child")
        elif name == "random_fold_in":
            self._record(env, eqn.invars[0], FOLD, eqn, ctx, depth)
            self._fresh_outs(env, eqn, depth, "fold-child")
        elif name == "random_bits":
            self._record(env, eqn.invars[0], SAMPLE, eqn, ctx, depth)
        elif name == "pjit":
            sub = eqn.params["jaxpr"]
            sub_env = self._enter(sub.jaxpr, eqn.invars, sub.consts, env,
                                  ctx, depth)
            self.walk(sub, sub_env, ctx, depth)
            self._propagate_outs(sub.jaxpr, sub_env, eqn, env)
        elif name in ("closed_call", "core_call", "custom_jvp_call",
                      "custom_vjp_call", "remat2", "checkpoint"):
            sub = eqn.params.get("call_jaxpr") or eqn.params.get("jaxpr")
            if sub is not None:
                inner = getattr(sub, "jaxpr", sub)
                consts = getattr(sub, "consts", ())
                if len(inner.invars) == len(eqn.invars):
                    sub_env = self._enter(inner, eqn.invars, consts, env,
                                          ctx, depth)
                    self.walk(sub, sub_env, ctx, depth)
                    self._propagate_outs(inner, sub_env, eqn, env)
        elif name == "cond":
            branches = eqn.params["branches"]
            operands = eqn.invars[1:]
            for idx, br in enumerate(branches):
                inner = getattr(br, "jaxpr", br)
                sub_env = self._enter(inner, operands,
                                      getattr(br, "consts", ()), env, ctx,
                                      depth)
                self.walk(br, sub_env, ctx + ((id(eqn), idx),), depth)
            self._fresh_outs(env, eqn, depth, "cond-out")
        elif name == "scan":
            sub = eqn.params["jaxpr"]
            inner = getattr(sub, "jaxpr", sub)
            n_consts = eqn.params["num_consts"]
            n_carry = eqn.params.get("num_carry",
                                     eqn.params.get("num_carries", 0))
            sub_env = {}
            for cv in inner.constvars:
                if _is_key_aval(cv.aval):
                    sub_env[cv] = _Key("const", depth)
            for i, bv in enumerate(inner.invars):
                op = eqn.invars[i] if i < len(eqn.invars) else None
                kid = self._get(env, op) if op is not None else None
                if i < n_consts + n_carry:
                    # consts/carries keep the outer identity: sampling one
                    # inside the body is a loop-invariant draw
                    if kid is not None:
                        sub_env[bv] = kid
                    elif _is_key_aval(bv.aval):
                        sub_env[bv] = _Key("binder", depth)
                else:
                    # xs rows: each iteration sees a distinct element
                    if kid is not None or _is_key_aval(bv.aval):
                        sub_env[bv] = _Key("scan-xs", depth + 1)
            self.walk(sub, sub_env, ctx, depth + 1)
            self._fresh_outs(env, eqn, depth, "scan-out")
        elif name == "while":
            for pkey, nconsts, c0 in (
                    ("cond_jaxpr", eqn.params["cond_nconsts"], 0),
                    ("body_jaxpr", eqn.params["body_nconsts"],
                     eqn.params["cond_nconsts"])):
                sub = eqn.params[pkey]
                inner = getattr(sub, "jaxpr", sub)
                n_carry_start = (eqn.params["cond_nconsts"]
                                 + eqn.params["body_nconsts"])
                operands = (eqn.invars[c0:c0 + nconsts]
                            + eqn.invars[n_carry_start:])
                sub_env = self._enter(inner, operands,
                                      getattr(sub, "consts", ()), env, ctx,
                                      depth)
                self.walk(sub, sub_env, ctx, depth + 1)
            self._fresh_outs(env, eqn, depth, "while-out")
        else:
            # unknown prim: opaque — key-typed outputs become fresh streams
            self._fresh_outs(env, eqn, depth, name)


# ---------------------------------------------------------------------------
# Contract evaluation
# ---------------------------------------------------------------------------


def check_jaxpr(closed, program: str,
                expect_fanout: Optional[int] = None) -> list:
    """Walk one ClosedJaxpr and return the Finding list."""
    w = _Walker()
    w.walk(closed)
    findings = []

    def _report(rule, ev, msg):
        findings.append(Finding("keycheck", rule, ev.path, ev.line,
                                f"[{program}] {msg}"))

    for evs in w.events.values():
        samples = [e for e in evs if e.kind == SAMPLE]
        splits = [e for e in evs if e.kind == SPLIT]
        derives = splits + [e for e in evs if e.kind == FOLD]
        pair = _any_conflicting_pair(samples, samples)
        if pair:
            _report("key-reuse", pair[1],
                    f"PRNG key sampled by ≥2 random primitives without an "
                    f"intervening split/fold_in (also sampled at "
                    f"{pair[0].path}:{pair[0].line})")
        pair = _any_conflicting_pair(samples, derives)
        if pair:
            _report("sample-then-derive", pair[0],
                    f"PRNG key is both sampled and split/folded "
                    f"(derived at {pair[1].path}:{pair[1].line}); derive "
                    f"a sub-key for the sample instead")
        pair = _any_conflicting_pair(splits, splits)
        if pair:
            _report("double-split", pair[1],
                    f"PRNG key split twice — the two child batches are "
                    f"identical streams (also split at "
                    f"{pair[0].path}:{pair[0].line})")
        for e in samples:
            if e.loop_invariant:
                _report("scan-invariant-sample", e,
                        "key originating outside a scan/while body is "
                        "sampled inside it — the same value is drawn "
                        "every iteration; fold_in the loop index first")
    if expect_fanout is not None and expect_fanout not in w.split_fanouts:
        findings.append(Finding(
            "keycheck", "per-agent-fanout", program, 0,
            f"[{program}] no {expect_fanout}-wide random_split found: the "
            f"K per-agent sampling streams must derive from one split of "
            f"the step key"))
    return findings


# ---------------------------------------------------------------------------
# Program inventory — the real builders, traced small
# ---------------------------------------------------------------------------

_K = 4          # agents in the RL programs
_FED_K = 3      # agents in the federated programs


def _rl_setup(algo: str):
    from repro.core import engine
    from repro.rl.envs import make_env
    env = make_env("cartpole(horizon=16)")
    if algo == "decbyzpg":
        from repro.core.decbyzpg import (DecByzPGConfig,
                                         build_decbyzpg_loop,
                                         init_decbyzpg_carry)
        cfg = DecByzPGConfig(K=_K, n_byz=1, attack="large_noise(sigma=1.0)",
                             aggregator="rfa", agreement="gda", kappa=2,
                             N=3, B=2, hidden=(8,))
        build, init = build_decbyzpg_loop, init_decbyzpg_carry
    else:
        from repro.core.byzpg import (ByzPGConfig, build_byzpg_loop,
                                      init_byzpg_carry)
        cfg = ByzPGConfig(K=_K, n_byz=1, attack="sign_flip",
                          aggregator="rfa", N=3, B=2, hidden=(8,))
        build, init = build_byzpg_loop, init_byzpg_carry
    return engine, env, cfg, build, init


def _trace_algo_loop(algo: str):
    T = 2
    engine, env, cfg, build, init = _rl_setup(algo)
    ks = engine.seed_keys(0)
    carry = init(env, cfg, ks.init)
    loop = build(env, cfg, T)
    return jax.make_jaxpr(loop)(*carry, jax.random.split(ks.loop, T),
                                ks.coin)


def _trace_lane_batch():
    engine, env, cfg, _, _ = _rl_setup("decbyzpg")
    fn = engine.lane_batch_loop(env, cfg, 2, ("eta",), 2, algo="decbyzpg")
    vals = jnp.array([[1e-2], [2e-2]], jnp.float32)
    seeds = jnp.arange(2, dtype=jnp.int32)
    return jax.make_jaxpr(fn)(vals, seeds)


def _fed_setup():
    from repro.configs import get_config, reduced
    from repro.distributed.fed_trainer import FedConfig
    cfg = reduced(get_config("llama3_2_1b"))
    fed = FedConfig(aggregator="rfa", kappa=2, n_byz=1,
                    attack="large_noise(sigma=1.0)")
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    mask = jax.ShapeDtypeStruct((_FED_K,), jnp.bool_)
    batch = {
        "tokens": jax.ShapeDtypeStruct((_FED_K, 2, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((_FED_K, 2, 16), jnp.int32),
    }
    return cfg, fed, key, mask, batch


def _trace_fed_step():
    from repro.distributed.fed_trainer import fed_train_step, init_fed_state
    cfg, fed, key, mask, batch = _fed_setup()
    state = jax.eval_shape(
        lambda k: init_fed_state(cfg, fed, _FED_K, k), key)
    coin = jax.ShapeDtypeStruct((), jnp.bool_)
    return jax.make_jaxpr(
        lambda s, b, m, k, c: fed_train_step(cfg, fed, s, b, m, k, large=c)
    )(state, batch, mask, key, coin)


def _trace_fed_step_flat():
    from repro.distributed.fed_trainer import (fed_train_step_flat,
                                               init_flat_fed_state)
    from repro.core import engine
    cfg, fed, key, mask, batch = _fed_setup()
    state, unravel = init_flat_fed_state(cfg, fed, _FED_K,
                                         engine.seed_keys(0).init)
    coin = jax.ShapeDtypeStruct((), jnp.bool_)
    return jax.make_jaxpr(
        lambda s, b, m, k, c: fed_train_step_flat(cfg, fed, s, unravel, b,
                                                  m, k, large=c)
    )(state, batch, mask, key, coin)


def _trace_fed_window():
    from repro.distributed.fed_trainer import fed_train_window, init_fed_state
    cfg, fed, key, mask, batch = _fed_setup()
    W = 2
    state = jax.eval_shape(
        lambda k: init_fed_state(cfg, fed, _FED_K, k), key)
    batches = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((W,) + s.shape, s.dtype), batch)
    ts = jax.ShapeDtypeStruct((W,), jnp.int32)
    return jax.make_jaxpr(
        lambda s, bs, m, t, k: fed_train_window(cfg, fed, s, bs, m, t, k)
    )(state, batches, mask, ts, key)


def programs() -> list:
    """(name, thunk -> ClosedJaxpr, expected per-agent fanout | None)."""
    return [
        ("decbyzpg_loop", lambda: _trace_algo_loop("decbyzpg"), _K),
        ("byzpg_loop", lambda: _trace_algo_loop("byzpg"), _K),
        ("lane_batch_loop", _trace_lane_batch, None),
        ("fed_train_step", _trace_fed_step, None),
        ("fed_train_step_flat", _trace_fed_step_flat, None),
        ("fed_train_window", _trace_fed_window, None),
    ]


def run(selected: Optional[Iterable[str]] = None) -> list:
    """Trace every inventory program and return all findings (deduped on
    (rule, path, line) so one bad helper reported through several
    programs surfaces once)."""
    findings, seen = [], set()
    for name, thunk, fanout in programs():
        if selected is not None and name not in selected:
            continue
        for f in check_jaxpr(thunk(), name, expect_fanout=fanout):
            dk = (f.rule, f.path, f.line)
            if dk not in seen:
                seen.add(dk)
                findings.append(f)
    return findings
