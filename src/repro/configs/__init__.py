from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape,
                                MLAConfig, MoEConfig, ModelConfig, SSMConfig,
                                XLSTMConfig, all_configs, get_config, reduced)
