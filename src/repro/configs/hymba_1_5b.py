"""Hymba-1.5B: parallel attention + mamba heads in each block.

[hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001
ssm_state=16 [arXiv:2411.13676].
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    sliding_window=None,
    fed_axis="data", recurrent_chunk=256,
    source="arXiv:2411.13676",
)
