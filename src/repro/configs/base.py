"""Config system: architecture configs, input shapes, registry.

Every assigned architecture gets one ``repro/configs/<id>.py`` defining
``CONFIG`` with the exact dimensions from the assignment. ``get_config(name)``
resolves by registry id; ``reduced(cfg)`` derives the CPU smoke-test variant
(2 layers, d_model<=512, <=4 experts) from the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""
    kv_lora_rank: int
    q_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by hybrid blocks)."""
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: alternating mLSTM / sLSTM blocks."""
    slstm_every: int = 2            # every n-th block is sLSTM, rest mLSTM
    proj_factor: float = 2.0        # mLSTM up-projection factor
    conv_dim: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # sub-configs (None if unused by the family)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # long-context serving: sliding-window variant used by long_500k decode
    long_context_window: int = 4096
    # attention sliding window in *all* modes (None = full causal)
    sliding_window: Optional[int] = None
    # modality frontend stub: none | vision | audio
    frontend: str = "none"
    n_prefix_embeds: int = 0        # prepended patch/frame embeddings
    # federation mapping (see DESIGN.md §3)
    fed_axis: str = "data"          # data | pod
    # shard the layer-stack dim over "data" (FSDP-over-layers; see
    # distributed/sharding.py) — for pod-federated archs too big otherwise
    fsdp_layers: bool = False
    # MLA decode: weight-absorbed latent attention (§Perf optimization;
    # False = naive expand-K/V-from-latent baseline)
    mla_absorb: bool = False
    # RMSNorm without materializing an f32 copy of the activations
    # (§Perf optimization; reduction still in f32)
    fused_rmsnorm: bool = False
    # recurrent scans: remat in time-chunks of this size (0 = plain scan
    # saving carry every step — §Perf baseline)
    recurrent_chunk: int = 0
    # small-model federation: replicate params per agent and use the model
    # axis for intra-agent batch parallelism instead of tensor parallelism
    # (one grad all-reduce per step instead of 2 per layer; §Perf)
    intra_agent_dp: bool = False
    source: str = ""                # citation from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params up to ties)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer = 0
        if self.family == "ssm":  # xLSTM
            x = self.xlstm
            d_in = int(d * x.proj_factor)
            m = d * d_in * 2 + 3 * d_in * (d_in // max(1, self.n_heads)) \
                + d_in * d_in + d_in * d + 2 * d
            s = 4 * d * d + 4 * d * d + d * d + 2 * d
            n_s = self.n_layers // x.slstm_every
            n_m = self.n_layers - n_s
            return emb + head + n_m * m + n_s * s + d
        # attention params
        if self.mla is not None:
            a = self.mla
            qk_hd = a.qk_nope_head_dim + a.qk_rope_head_dim
            attn = (d * a.q_lora_rank + a.q_lora_rank * self.n_heads * qk_hd
                    + d * (a.kv_lora_rank + a.qk_rope_head_dim)
                    + a.kv_lora_rank * self.n_heads
                    * (a.qk_nope_head_dim + a.v_head_dim)
                    + self.n_heads * a.v_head_dim * d)
        else:
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
        # mlp params
        if self.moe is not None:
            m = self.moe
            mlp = m.n_experts * 3 * d * m.d_ff_expert \
                + m.n_shared_experts * 3 * d * m.d_ff_expert \
                + d * m.n_experts
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            dtr = s.dt_rank or -(-d // 16)
            per_layer += (d * 2 * d_in + s.conv_dim * d_in
                          + d_in * (dtr + 2 * s.state_dim) + dtr * d_in
                          + d_in * s.state_dim + d_in + d_in * d)
        return emb + head + self.n_layers * per_layer + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return self.n_params() - self.n_layers * inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "pixtral_12b",
    "llama3_2_1b",
    "hymba_1_5b",
    "xlstm_350m",
    "minicpm3_4b",
    "musicgen_medium",
    "grok_1_314b",
    "qwen2_7b",
    "qwen2_5_3b",
    "deepseek_v2_lite_16b",
)

_ALIASES = {
    "pixtral-12b": "pixtral_12b",
    "llama3.2-1b": "llama3_2_1b",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-350m": "xlstm_350m",
    "minicpm3-4b": "minicpm3_4b",
    "musicgen-medium": "musicgen_medium",
    "grok-1-314b": "grok_1_314b",
    "qwen2-7b": "qwen2_7b",
    "qwen2.5-3b": "qwen2_5_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
}


def get_config(name: str) -> ModelConfig:
    key = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown architecture {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> Tuple[ModelConfig, ...]:
    return tuple(get_config(a) for a in ARCH_IDS)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests.

    2 layers, d_model<=512, <=4 experts, small vocab.
    """
    d = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        n_layers=2, d_model=d, n_heads=n_heads, n_kv_heads=n_kv,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=d // n_heads,
        long_context_window=64,
        n_prefix_embeds=min(cfg.n_prefix_embeds, 8),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_head_dim=16, qk_rope_head_dim=16,
                              v_head_dim=16)
        kw["head_dim"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8)
    if cfg.xlstm is not None:
        kw["xlstm"] = cfg.xlstm
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **kw)
