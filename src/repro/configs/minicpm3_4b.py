"""MiniCPM3-4B with multi-head latent attention (MLA).

[dense] 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448
[hf:openbmb/MiniCPM3-4B]. MLA dims follow the model card:
q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=96,
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    fed_axis="pod", mla_absorb=True,
    source="hf:openbmb/MiniCPM3-4B",
)
