"""Pixtral-12B backbone: pixtral-ViT + mistral-nemo decoder.

[vlm] 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409]. Vision encoder is a stub frontend:
input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1_000_000.0,
    frontend="vision", n_prefix_embeds=256,
    fed_axis="pod",
    source="hf:mistralai/Pixtral-12B-2409",
)
