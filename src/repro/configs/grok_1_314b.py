"""Grok-1 (314B): 8-expert top-2 MoE.

[moe] 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072
MoE 8e top-2 [hf:xai-org/grok-1].
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=0,
                  d_ff_expert=32768),
    fed_axis="pod", fsdp_layers=True,
    source="hf:xai-org/grok-1",
)
