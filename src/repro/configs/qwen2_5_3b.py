"""Qwen2.5-3B. [dense] 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, QKV bias [hf:Qwen/Qwen2.5-0.5B family card]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936, head_dim=128,
    rope_theta=1_000_000.0, qkv_bias=True, tie_embeddings=True,
    fed_axis="data",
    source="hf:Qwen/Qwen2.5-0.5B",
)
