"""DeepSeek-V2-Lite (16B): MLA (kv_lora=512) + MoE 64e top-6, 2 shared.

[moe] 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400
[arXiv:2405.04434]. See DESIGN.md for the '64e vs 160 routed' note.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=192,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2,
                  d_ff_expert=1408),
    fed_axis="pod", mla_absorb=True,
    source="arXiv:2405.04434",
)
