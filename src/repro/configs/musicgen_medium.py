"""MusicGen-medium decoder backbone over EnCodec tokens.

[audio] 48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048
[arXiv:2306.05284]. The mel/EnCodec conv frontend is a stub:
input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    frontend="audio", n_prefix_embeds=128,
    fed_axis="data",
    source="arXiv:2306.05284",
)
