"""xLSTM-350M: alternating sLSTM + mLSTM blocks (no FFN, d_ff=0).

[ssm] 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517].
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_dim=4),
    fed_axis="data", recurrent_chunk=256,
    source="arXiv:2405.04517",
)
