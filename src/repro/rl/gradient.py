"""Policy-gradient estimators: REINFORCE and GPOMDP (paper App. A.1),
with the importance-weighted estimator ``g^{ω_θt}(τ | θ_{t-1})`` used by the
PAGE correction (Assumption 5 / SVRPG-style, weight not differentiated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import register, resolve
from repro.rl.policy import policy_logits
from repro.rl.rollout import Trajectory


def step_log_probs(params, traj: Trajectory, activation="tanh"):
    """(H,) log π_θ(a_h | s_h), masked. ``activation`` is a policy logits
    spec — an MLP activation string or a callable (params, obs) ->
    logits."""
    logits = policy_logits(params, traj.obs, activation)    # (H, A)
    lp = jax.nn.log_softmax(logits)
    lp = jnp.take_along_axis(lp, traj.actions[..., None], axis=-1)[..., 0]
    return lp * traj.mask


def _gpomdp_surrogate(params, traj, gamma, baseline, activation):
    """Σ_h (Σ_{t<=h} log π_t) (γ^h r_h − b_h)  — gradient = GPOMDP."""
    lp = step_log_probs(params, traj, activation)
    H = lp.shape[-1]
    disc_r = traj.rewards * gamma ** jnp.arange(H) - baseline * traj.mask
    cum_lp = jnp.cumsum(lp, axis=-1)
    return jnp.sum(cum_lp * jax.lax.stop_gradient(disc_r), axis=-1)


def _reinforce_surrogate(params, traj, gamma, baseline, activation):
    lp = step_log_probs(params, traj, activation)
    H = lp.shape[-1]
    g_return = jnp.sum(traj.rewards * gamma ** jnp.arange(H), axis=-1)
    return jnp.sum(lp, axis=-1) * jax.lax.stop_gradient(g_return - baseline)


register("estimator", "gpomdp")(lambda: _gpomdp_surrogate)
register("estimator", "reinforce")(lambda: _reinforce_surrogate)


def _surrogate(estimator):
    """Resolve an estimator spec (name string or Spec) to its surrogate."""
    return resolve("estimator", estimator)


def grad_estimate(params, traj: Trajectory, gamma: float,
                  baseline: float = 0.0, estimator: str = "gpomdp",
                  activation: str = "tanh", sample_weights=None):
    """(1/M) Σ_i g(τ_i | θ): mean PG over a (M, H, ...) trajectory batch.

    ``sample_weights`` (M,), summing to 1, replaces the uniform 1/M mean —
    the fused engine uses it to mask a fixed max(N, B)-shaped batch down to
    the B trajectories a small PAGE step actually consumes.
    """
    sur = _surrogate(estimator)

    def loss(p):
        s = jax.vmap(lambda t: sur(p, t, gamma, baseline, activation)
                     )(traj)
        if sample_weights is None:
            return jnp.mean(s)
        return jnp.sum(sample_weights * s)

    return jax.grad(loss)(params)


def importance_weights(params_old, params_new, traj: Trajectory,
                       activation="tanh", clip: float = 10.0):
    """ω(τ | θ_new, θ_old) = p(τ|θ_old)/p(τ|θ_new), τ ~ p(·|θ_new).

    Clipped for numerical stability (standard SVRPG practice).
    """
    lp_old = jax.vmap(lambda t: jnp.sum(step_log_probs(params_old, t,
                                                       activation)))(traj)
    lp_new = jax.vmap(lambda t: jnp.sum(step_log_probs(params_new, t,
                                                       activation)))(traj)
    w = jnp.exp(jnp.clip(lp_old - lp_new, -jnp.log(clip), jnp.log(clip)))
    return jax.lax.stop_gradient(w)


def weighted_grad_estimate(params_old, params_new, traj: Trajectory,
                           gamma: float, baseline: float = 0.0,
                           estimator: str = "gpomdp", activation="tanh",
                           sample_weights=None,
                           self_normalized: bool = False):
    """(1/M) Σ_i g^{ω_θnew}(τ_i | θ_old): IS-corrected PG at θ_old from
    trajectories sampled at θ_new. ``sample_weights`` as in
    :func:`grad_estimate`.

    ``self_normalized=True`` divides by the realized weight mass
    (Σ w_i s_i / Σ w_i instead of (1/M) Σ w_i s_i): the classic
    self-normalized IS estimator — biased O(1/M) but consistent, with
    much lower variance when the weights are spread out. The PAGE
    correction keeps the plain (unbiased) form per Assumption 5; the
    normalizer is treated as a constant (not differentiated), matching
    the non-differentiated weights.
    """
    w = importance_weights(params_old, params_new, traj, activation)
    if self_normalized:
        mass = jnp.sum(sample_weights * w) if sample_weights is not None \
            else jnp.mean(w)
        w = w / jnp.maximum(mass, 1e-12)
    sur = _surrogate(estimator)

    def loss(p):
        s = jax.vmap(lambda t: sur(p, t, gamma, baseline, activation))(traj)
        if sample_weights is None:
            return jnp.mean(w * s)
        return jnp.sum(sample_weights * w * s)

    return jax.grad(loss)(params_old)
