"""Trajectory sampling with ``lax.scan`` (fixed horizon H, absorbing done)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.policy import policy_logits


class Trajectory(NamedTuple):
    obs: jnp.ndarray        # (H, obs_dim)
    actions: jnp.ndarray    # (H,) int32
    rewards: jnp.ndarray    # (H,)
    mask: jnp.ndarray       # (H,) 1.0 while episode alive


def sample_trajectory(env, params, key, activation="tanh",
                      logit_scale=1.0) -> Trajectory:
    """``activation`` is a policy logits spec: an MLP activation string, or
    a callable ``(params, obs) -> logits`` (e.g. a transformer policy)."""
    k_reset, k_steps = jax.random.split(key)
    s0 = env.reset(k_reset)

    def body(carry, k):
        s, alive = carry
        obs = env.observe(s)
        logits = policy_logits(params, obs, activation) * logit_scale
        a = jax.random.categorical(k, logits)
        s2, r, done = env.step(s, a)
        # freeze the state once done; mask future rewards
        s_next = jax.tree.map(lambda new, old: jnp.where(alive, new, old),
                              s2, s)
        out = (obs, a, r * alive, alive)
        return (s_next, alive * (1.0 - done.astype(jnp.float32))), out

    (_, _), (obs, actions, rewards, mask) = jax.lax.scan(
        body, (s0, jnp.float32(1.0)), jax.random.split(k_steps, env.horizon))
    return Trajectory(obs, actions, rewards, mask)


def sample_batch(env, params, key, n: int, activation="tanh",
                 logit_scale=1.0) -> Trajectory:
    """(n, H, ...) batch of trajectories."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: sample_trajectory(env, params, k, activation,
                                                logit_scale))(keys)


def batch_return(traj: Trajectory, gamma: float = 1.0) -> jnp.ndarray:
    H = traj.rewards.shape[-1]
    disc = gamma ** jnp.arange(H)
    return jnp.sum(traj.rewards * disc, axis=-1)
