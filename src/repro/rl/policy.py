"""Categorical MLP policies (paper Table 1: 16,16 ReLU for CartPole,
64,64 Tanh for LunarLander)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_sizes(env, hidden) -> tuple:
    """Layer sizes of the policy for ``env`` with the given hidden spec."""
    return (env.obs_dim, *hidden, env.n_actions)


def mlp_unraveler(env, hidden):
    """(unravel_fn, d) for the flat policy vector — derived from a template
    init (shapes only, seed-free), shared by the fused training loops."""
    from jax.flatten_util import ravel_pytree
    vec, unravel = ravel_pytree(init_mlp(jax.random.PRNGKey(0),
                                         mlp_sizes(env, hidden)))
    return unravel, vec.shape[0]


def init_mlp(key, sizes, dtype=jnp.float32):
    """sizes: (obs_dim, h1, ..., n_actions)."""
    params = []
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        w = jax.random.normal(k1, (din, dout), dtype) * (din ** -0.5)
        params.append({"w": w, "b": jnp.zeros((dout,), dtype)})
    return params


def mlp_logits(params, obs, activation="tanh"):
    act = jnp.tanh if activation == "tanh" else jax.nn.relu
    x = obs
    for layer in params[:-1]:
        x = act(x @ layer["w"] + layer["b"])
    return x @ params[-1]["w"] + params[-1]["b"]


def log_prob(params, obs, action, activation="tanh"):
    logits = mlp_logits(params, obs, activation)
    return jax.nn.log_softmax(logits)[..., action]


def sample_action(params, obs, key, activation="tanh"):
    logits = mlp_logits(params, obs, activation)
    a = jax.random.categorical(key, logits)
    return a, jax.nn.log_softmax(logits)[a]
