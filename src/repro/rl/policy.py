"""Categorical policies (paper Table 1: 16,16 ReLU for CartPole,
64,64 Tanh for LunarLander — plus the registry ``policy`` namespace that
lets a config name any logits model, e.g. a transformer from
``repro/models`` whose params ravel into the same flat θ stack).

A :class:`Policy` couples an ``init(key) -> params`` with a *logits spec*:
either an activation string (the historical MLP path — numerics and
compiled programs are unchanged) or a callable
``logits(params, obs) -> (..., n_actions)``. The rollout/gradient code
accepts both via :func:`policy_logits`.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.registry import register, resolve


class Policy(NamedTuple):
    """A resolved policy: parameter init + how to compute action logits.

    ``logits`` is either an activation name (str — run the MLP stack) or a
    callable ``(params, obs) -> logits`` (arbitrary models; obs may carry
    leading batch dims).

    ``model_cfg``/``n_actions`` mark a policy as *servable*: when the
    logits model is a ``repro/models`` architecture, the attached
    :class:`~repro.configs.base.ModelConfig` lets ``repro.serving`` build
    a decode engine for it (``n_actions`` restricts the greedy head to
    the action logits).  MLP policies leave both ``None`` — they have no
    token stream to decode.
    """
    init: Callable
    logits: object
    model_cfg: object = None
    n_actions: object = None


def policy_logits(params, obs, logits="tanh"):
    """Dispatch on the logits spec: activation string -> MLP; callable ->
    the policy's own model."""
    if callable(logits):
        return logits(params, obs)
    return mlp_logits(params, obs, logits)


def policy_unraveler(policy: Policy):
    """(unravel_fn, d) for the flat policy vector — from a template init
    whose values are discarded (``ravel_pytree`` keeps only the tree
    structure and leaf shapes), so the fixed key never reaches a sampled
    stream — the sanctioned shape-only exemption (DESIGN.md §7)."""
    from jax.flatten_util import ravel_pytree
    vec, unravel = ravel_pytree(
        policy.init(jax.random.PRNGKey(0)))     # analysis: shape-only
    return unravel, vec.shape[0]


@register("policy", "mlp")
def _mlp_policy_factory(env, hidden=None, activation=None,
                        cfg_hidden=(16, 16), cfg_activation="tanh"):
    """The default policy. ``cfg_hidden``/``cfg_activation`` carry the
    algorithm config's fields; explicit spec kwargs
    (``mlp(hidden=(32,32))``) win over them."""
    h = tuple(cfg_hidden if hidden is None else hidden)
    act = cfg_activation if activation is None else activation
    return Policy(init=lambda key: init_mlp(key, mlp_sizes(env, h)),
                  logits=act)


def resolve_policy(cfg, env) -> Policy:
    """Resolve an algorithm config's ``policy`` spec (``"mlp"`` when the
    config predates the field), feeding ``cfg.hidden``/``cfg.activation``
    as the MLP defaults."""
    return resolve("policy", getattr(cfg, "policy", "mlp"), env=env,
                   cfg_hidden=tuple(cfg.hidden),
                   cfg_activation=cfg.activation)


def mlp_sizes(env, hidden) -> tuple:
    """Layer sizes of the policy for ``env`` with the given hidden spec."""
    return (env.obs_dim, *hidden, env.n_actions)


def mlp_unraveler(env, hidden):
    """(unravel_fn, d) for the flat policy vector — derived from a template
    init whose values are discarded (shape-only, see
    :func:`policy_unraveler`)."""
    from jax.flatten_util import ravel_pytree
    vec, unravel = ravel_pytree(
        init_mlp(jax.random.PRNGKey(0),         # analysis: shape-only
                 mlp_sizes(env, hidden)))
    return unravel, vec.shape[0]


def init_mlp(key, sizes, dtype=jnp.float32):
    """sizes: (obs_dim, h1, ..., n_actions)."""
    params = []
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        w = jax.random.normal(k1, (din, dout), dtype) * (din ** -0.5)
        params.append({"w": w, "b": jnp.zeros((dout,), dtype)})
    return params


def mlp_logits(params, obs, activation="tanh"):
    act = jnp.tanh if activation == "tanh" else jax.nn.relu
    x = obs
    for layer in params[:-1]:
        x = act(x @ layer["w"] + layer["b"])
    return x @ params[-1]["w"] + params[-1]["b"]


def log_prob(params, obs, action, activation="tanh"):
    logits = mlp_logits(params, obs, activation)
    return jax.nn.log_softmax(logits)[..., action]


def sample_action(params, obs, key, activation="tanh"):
    logits = mlp_logits(params, obs, activation)
    a = jax.random.categorical(key, logits)
    return a, jax.nn.log_softmax(logits)[a]
