"""Transformer policies: any ``repro/models`` architecture as the
categorical policy network.

Registered under the ``policy`` namespace as ``"transformer"``, so a
``DecByzPGConfig(policy="transformer(arch='qwen2.5-3b')")`` trains a
transformer whose parameters ravel into the same flat (K, D) stack the
robust aggregators operate on — at a D where the Gram-space sharded
aggregation path (DESIGN.md §3) is the only one that fits per device.

The observation enters the model as a single projected prefix embedding
(the config's modality-frontend slot): obs is written into the leading
``obs_dim`` coordinates of a (B, 1, d_model) prefix, a BOS token anchors
the text side, and the action logits are the first ``n_actions`` entries
of the last-position LM head output.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core.registry import register
from repro.models import forward, init_params
from repro.rl.policy import Policy


def transformer_policy_config(arch: str = "qwen2.5-3b", n_layers=None,
                              d_model=None, n_heads=None, d_ff=None):
    """Policy-sized model config: ``reduced(arch)`` with the modality
    frontend enabled (one prefix embedding carries the observation).
    Optional overrides shrink it further for tests."""
    cfg = reduced(get_config(arch))
    kw = {"frontend": "state", "n_prefix_embeds": 1}
    if n_layers is not None:
        kw["n_layers"] = int(n_layers)
    if n_heads is not None:
        kw["n_heads"] = int(n_heads)
        kw["n_kv_heads"] = min(cfg.n_kv_heads, int(n_heads))
    if d_model is not None:
        kw["d_model"] = int(d_model)
    if d_ff is not None:
        kw["d_ff"] = int(d_ff)
    if (d_model is not None or n_heads is not None) and cfg.mla is None:
        d = kw.get("d_model", cfg.d_model)
        h = kw.get("n_heads", cfg.n_heads)
        if d % h:
            raise ValueError(f"d_model={d} not divisible by n_heads={h}")
        kw["head_dim"] = d // h
    return dataclasses.replace(cfg, **kw)


@register("policy", "transformer")
def _transformer_policy_factory(env, arch: str = "qwen2.5-3b",
                                n_layers=None, d_model=None, n_heads=None,
                                d_ff=None, remat: bool = False):
    """``policy="transformer(arch='qwen2.5-3b', n_layers=1, ...)"``.

    ``remat`` defaults off: the policy runs on length-2 sequences where
    checkpointing each layer only adds recompute.
    """
    cfg = transformer_policy_config(arch, n_layers=n_layers,
                                    d_model=d_model, n_heads=n_heads,
                                    d_ff=d_ff)
    if cfg.d_model < env.obs_dim:
        raise ValueError(f"transformer policy d_model={cfg.d_model} < "
                         f"obs_dim={env.obs_dim} for env {env.name!r}")
    if cfg.vocab_size < env.n_actions:
        raise ValueError(f"transformer policy vocab_size={cfg.vocab_size} "
                         f"< n_actions={env.n_actions}")
    n_actions = env.n_actions
    obs_dim = env.obs_dim

    def logits_fn(params, obs):
        """obs (..., obs_dim) -> logits (..., n_actions); leading dims are
        flattened into the forward batch and restored."""
        lead = obs.shape[:-1]
        ob = obs.reshape((-1, obs_dim))
        B = ob.shape[0]
        prefix = jnp.zeros((B, 1, cfg.d_model), ob.dtype)
        prefix = prefix.at[:, 0, :obs_dim].set(ob)
        bos = jnp.zeros((B, 1), jnp.int32)
        logits, _, _ = forward(cfg, params, tokens=bos,
                               prefix_embeds=prefix, last_only=True,
                               remat=remat)
        return logits[:, -1, :n_actions].reshape((*lead, n_actions))

    return Policy(init=lambda key: init_params(cfg, key), logits=logits_fn,
                  model_cfg=cfg, n_actions=n_actions)
