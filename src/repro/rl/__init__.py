from repro.rl.envs import Env, make_cartpole, make_env, make_lunarlander
from repro.rl.gradient import (grad_estimate, importance_weights,
                               step_log_probs, weighted_grad_estimate)
from repro.rl.policy import init_mlp, mlp_logits
from repro.rl.rollout import Trajectory, batch_return, sample_batch
