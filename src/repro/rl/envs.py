"""Pure-JAX episodic environments (fixed horizon, absorbing termination).

CartPole follows the classic Barto-Sutton-Anderson dynamics [31]. The paper's
second benchmark is Box2D LunarLander; we implement `LunarLanderLite`, a
faithful-in-spirit 2D thrust/gravity lander with leg contacts, shaping
rewards, crash/landing terminals — pure JAX so rollouts jit/vmap (noted in
DESIGN.md as an adaptation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import Spec, register, resolve


@dataclasses.dataclass(frozen=True)
class Env:
    name: str
    obs_dim: int
    n_actions: int
    horizon: int
    reset: Callable          # key -> state
    step: Callable           # (state, action) -> (state, reward, done)
    observe: Callable        # state -> obs


# ---------------------------------------------------------------------------
# CartPole
# ---------------------------------------------------------------------------

def make_cartpole(horizon: int = 200) -> Env:
    g, mc, mp, lp, f, dt = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02
    mt = mc + mp
    pml = mp * lp

    def reset(key):
        return jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)

    def step(s, a):
        x, xd, th, thd = s
        force = jnp.where(a == 1, f, -f)
        ct, st = jnp.cos(th), jnp.sin(th)
        tmp = (force + pml * thd ** 2 * st) / mt
        thdd = (g * st - ct * tmp) / (lp * (4.0 / 3.0 - mp * ct ** 2 / mt))
        xdd = tmp - pml * thdd * ct / mt
        s2 = jnp.stack([x + dt * xd, xd + dt * xdd,
                        th + dt * thd, thd + dt * thdd])
        done = (jnp.abs(s2[0]) > 2.4) | (jnp.abs(s2[2]) > 12 * jnp.pi / 180)
        return s2, 1.0, done

    return Env("cartpole", 4, 2, horizon, reset, step, lambda s: s)


# ---------------------------------------------------------------------------
# LunarLander-lite
# ---------------------------------------------------------------------------

def make_lunarlander(horizon: int = 300) -> Env:
    g, dt = -1.6, 0.05
    main_t, side_t = 6.0, 0.6

    def reset(key):
        k1, k2 = jax.random.split(key)
        x0 = jax.random.uniform(k1, (), minval=-0.3, maxval=0.3)
        vx0 = jax.random.uniform(k2, (), minval=-0.3, maxval=0.3)
        # state: x, y, vx, vy, theta, omega
        return jnp.array([x0, 1.4, vx0, 0.0, 0.0, 0.0])

    def potential(s):
        x, y, vx, vy, th, om = s
        return (-10.0 * jnp.sqrt(x ** 2 + y ** 2)
                - 10.0 * jnp.sqrt(vx ** 2 + vy ** 2)
                - 10.0 * jnp.abs(th))

    def step(s, a):
        x, y, vx, vy, th, om = s
        main = (a == 2).astype(jnp.float32)
        left = (a == 1).astype(jnp.float32)
        right = (a == 3).astype(jnp.float32)
        fx = main * main_t * (-jnp.sin(th))
        fy = main * main_t * jnp.cos(th) + g
        torque = (left - right) * side_t
        vx2, vy2 = vx + dt * fx, vy + dt * fy
        x2, y2 = x + dt * vx2, y + dt * vy2
        om2 = om + dt * torque
        th2 = th + dt * om2
        s2 = jnp.array([x2, y2, vx2, vy2, th2, om2])
        landed_zone = (jnp.abs(x2) < 0.25) & (jnp.abs(vx2) < 0.6) & \
            (jnp.abs(vy2) < 0.6) & (jnp.abs(th2) < 0.3)
        touch = y2 <= 0.0
        out = jnp.abs(x2) > 1.5
        done = touch | out
        shaped = potential(s2) - potential(s)
        fuel = -0.3 * main - 0.03 * (left + right)
        terminal = jnp.where(touch & landed_zone, 100.0,
                             jnp.where(touch | out, -100.0, 0.0))
        r = shaped + fuel + terminal
        return s2, r, done

    return Env("lunarlander", 6, 4, horizon, reset, step, lambda s: s)


register("env", "cartpole")(make_cartpole)
register("env", "lunarlander")(make_lunarlander)


def make_env(name, **kw) -> Env:
    """Build an env from a spec (``"cartpole"``, ``"cartpole(horizon=100)"``,
    or a Spec); extra ``kw`` merge into the spec's kwargs."""
    if isinstance(name, Env):
        if kw:
            raise TypeError(f"cannot apply overrides {sorted(kw)} to an "
                            f"already-built Env ({name.name}); pass a "
                            f"spec instead")
        return name
    spec = Spec.of(name)
    if kw:
        spec = spec.with_kwargs(**kw)
    return resolve("env", spec)
