"""repro.obs — zero-overhead-off telemetry (DESIGN.md §8).

Three planes:

* **In-loop metric taps** (:mod:`repro.obs.metrics`): fused loops built
  with a static ``cfg.telemetry=True`` stream per-iteration scalars and
  small vectors (honest gradient norm, agreement diameter Δ₂, per-round
  rejected-agent masks) through ``jax.debug.callback`` into host ring
  buffers and attached sinks. Off (the default) the compiled program is
  the exact seed program.
* **Span tracing** (:mod:`repro.obs.trace`): ``jax.named_scope`` phase
  names inside telemetry-enabled programs, plus a host Chrome-trace
  tracer around engine compiles/dispatches (Perfetto-loadable;
  ``--profile`` on ``repro.launch.train``).
* **Sinks + manifest** (:mod:`repro.obs.sinks` /
  :mod:`repro.obs.manifest`): JSONL / in-memory / stdout-progress sinks
  and a per-run manifest including the kernel backend-dispatch counters.

Typical use::

    from repro import obs
    with obs.telemetry(obs.JsonlSink("metrics.jsonl")):
        out = run_decbyzpg(env, dataclasses.replace(cfg, telemetry=True), T)
    print(out["aggregator_confusion"]["recall"])
"""
from repro.obs.manifest import build_manifest, write_manifest
from repro.obs.metrics import (RingBuffer, Recorder, capture,
                               confusion_tally, disable, enable, enabled,
                               get_recorder, progress, record, tap,
                               telemetry)
from repro.obs.sinks import (JsonlSink, MemorySink, Sink,
                             StdoutProgressSink)
from repro.obs.trace import (Tracer, get_tracer, host_instant, host_span,
                             named_phase, write_trace)

__all__ = [
    "RingBuffer", "Recorder", "Sink", "MemorySink", "JsonlSink",
    "StdoutProgressSink", "Tracer",
    "enabled", "enable", "disable", "telemetry", "capture",
    "get_recorder", "record", "progress", "tap", "confusion_tally",
    "named_phase", "host_span", "host_instant", "get_tracer",
    "write_trace", "build_manifest", "write_manifest",
]
