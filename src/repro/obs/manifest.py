"""Per-run manifest (DESIGN.md §8): the static facts a telemetry stream
needs to be interpretable after the fact — versions, devices, mesh,
compile counts, and the kernel-dispatch counters (which make a silent
``auto_jnp_below`` fallback visible instead of only a 2x bench miss).
"""
from __future__ import annotations

import json
import time
from typing import Optional

import jax


def build_manifest(extra: Optional[dict] = None) -> dict:
    """Snapshot the run environment. ``extra`` merges caller-provided
    facts (spec strings, CLI args, mesh axis names)."""
    from repro.core import engine
    from repro.kernels import dispatch
    m = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "devices": [str(d) for d in jax.devices()],
        "compiled_loop_cache_entries": engine.compile_count(),
        "kernel_dispatch_counts": {
            f"{name}:{backend}:{reason}": n
            for (name, backend, reason), n
            in sorted(dispatch.dispatch_counts().items())},
    }
    if extra:
        m.update(extra)
    return m


def write_manifest(path: str, extra: Optional[dict] = None) -> dict:
    doc = build_manifest(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return doc
