"""In-loop metric taps and the host-side recorder (DESIGN.md §8).

Two switches govern telemetry, by design:

* ``cfg.telemetry`` (a *static* config field participating in
  ``engine.static_key``) decides whether a fused loop's compiled program
  contains tap callbacks at all. Off (the default) the program is the
  exact seed program — no dead ``debug_callback`` in the jaxpr, same
  compile-cache entries.
* :func:`enable` / :func:`disable` (process-global) gate the *host-side*
  instrumentation — engine spans, ``run_grid`` progress events, manifest
  records — which must cost nothing when off.

A telemetry-enabled program emits per-iteration records through
:func:`tap`, which lowers to one ``jax.debug.callback`` per scan step;
records land in per-stream ring buffers on the :class:`Recorder` and fan
out to any attached sinks. Under a vmapped lane batch the callback fires
once per batch row per iteration (JAX's batching rule unrolls it), so the
stream interleaves rows; the stacked histories returned by the loop remain
the per-scenario source of truth — the stream is for live observation.

Taps never consume PRNG keys and never perturb the numerics: a run with
``telemetry=True`` returns bit-identical histories to the same run with
``telemetry=False`` (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

import collections
import contextlib
import functools
from typing import Optional

import jax
import numpy as np

from repro.obs.sinks import MemorySink, Sink, StdoutProgressSink

#: default per-stream ring-buffer capacity (records, not bytes)
RING_CAPACITY = 4096

_ENABLED = [False]


def enabled() -> bool:
    """Is host-side instrumentation (spans, progress, records) on?"""
    return _ENABLED[0]


def enable() -> None:
    _ENABLED[0] = True


def disable() -> None:
    _ENABLED[0] = False


class RingBuffer:
    """Bounded per-stream record store (newest ``capacity`` records)."""

    def __init__(self, capacity: int = RING_CAPACITY):
        self._q = collections.deque(maxlen=capacity)
        self.dropped = 0          # records evicted since creation

    def append(self, record: dict) -> None:
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(record)

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def latest(self) -> Optional[dict]:
        return self._q[-1] if self._q else None


class Recorder:
    """Per-stream ring buffers + attached sinks.

    ``record(stream, payload)`` always lands in the stream's ring buffer
    (cheap, bounded) and fans out to every attached sink. The default
    recorder carries one :class:`StdoutProgressSink` so ``progress``
    lines reach the terminal with no setup.
    """

    def __init__(self, capacity: int = RING_CAPACITY,
                 sinks: Optional[list] = None):
        self.capacity = capacity
        self.streams: dict = {}
        self.sinks: list = list(sinks) if sinks is not None \
            else [StdoutProgressSink()]

    def record(self, stream: str, payload: dict) -> None:
        buf = self.streams.get(stream)
        if buf is None:
            buf = self.streams[stream] = RingBuffer(self.capacity)
        rec = {"stream": stream, **payload}
        buf.append(rec)
        for sink in self.sinks:
            sink.emit(rec)

    def add_sink(self, sink: Sink) -> Sink:
        self.sinks.append(sink)
        return sink

    def remove_sink(self, sink: Sink) -> None:
        self.sinks.remove(sink)

    def stream(self, name: str) -> list:
        """Snapshot of one stream's ring buffer (oldest first)."""
        return list(self.streams.get(name, ()))

    def clear(self) -> None:
        self.streams.clear()

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


_RECORDER = Recorder()


def get_recorder() -> Recorder:
    return _RECORDER


def record(stream: str, **payload) -> None:
    """Host-side record (engine spans, grid progress, manifests)."""
    _RECORDER.record(stream, payload)


def progress(message: str, **fields) -> None:
    """One progress line through the recorder's stdout sink — the single
    reporting path for benchmarks/examples entry points (replaces their
    historical ad-hoc ``print`` calls)."""
    _RECORDER.record("progress", {"message": message, **fields})


@contextlib.contextmanager
def telemetry(*sinks: Sink, keep: bool = False):
    """Enable host-side instrumentation and attach ``sinks`` for the
    scope; yields the recorder. ``keep=True`` leaves attached sinks in
    place on exit (callers manage ``close``)."""
    prev = _ENABLED[0]
    _ENABLED[0] = True
    for s in sinks:
        _RECORDER.add_sink(s)
    try:
        yield _RECORDER
    finally:
        _ENABLED[0] = prev
        if not keep:
            for s in sinks:
                _RECORDER.remove_sink(s)
                s.close()


@contextlib.contextmanager
def capture(stream: Optional[str] = None):
    """Collect records into a fresh :class:`MemorySink` for the scope
    (optionally filtered to one stream); yields the sink."""
    sink = MemorySink()
    with telemetry(sink):
        yield sink
    if stream is not None:
        sink.records = [r for r in sink.records
                        if r.get("stream") == stream]


def _tap_host(stream: str, **values) -> None:
    """Host target of the in-loop tap callback: route by stream name at
    call time (the compiled program is cached and shared across runs, so
    it must not capture a recorder instance)."""
    _RECORDER.record(stream, {k: np.asarray(v) for k, v in values.items()})


def tap(stream: str, **values) -> None:
    """Emit per-iteration values from inside a traced fused loop.

    Only call under a static ``cfg.telemetry`` check — the callback is
    baked into the compiled program, which is exactly why the off path
    must never reach this function. Values must not include PRNG keys
    (taps are observers, not consumers of the key stream)."""
    jax.debug.callback(functools.partial(_tap_host, stream), **values)


# ---------------------------------------------------------------------------
# Byzantine forensics: rejected-mask confusion tally
# ---------------------------------------------------------------------------


def confusion_tally(rejected, n_byz: int) -> dict:
    """Confusion tally of per-round rejected-agent masks vs the ground
    truth Byzantine set (agents ``0..n_byz-1`` by construction).

    ``rejected``: bool array ``(..., K)`` — any number of leading axes
    (rounds, seeds, lanes) is summed over. Returns counts plus
    precision/recall of the aggregator viewed as a Byzantine detector
    (the ``Experiment.summary()`` ``aggregator_precision/recall``
    metric)."""
    rej = np.asarray(rejected).astype(bool)
    K = rej.shape[-1]
    truth = np.arange(K) < n_byz
    flat = rej.reshape(-1, K)
    tp = int(np.sum(flat & truth))
    fp = int(np.sum(flat & ~truth))
    fn = int(np.sum(~flat & truth))
    tn = int(np.sum(~flat & ~truth))
    return {
        "rounds": int(flat.shape[0]), "n_byz": int(n_byz), "K": int(K),
        "tp": tp, "fp": fp, "fn": fn, "tn": tn,
        "precision": tp / (tp + fp) if tp + fp else 0.0,
        "recall": tp / (tp + fn) if tp + fn else 0.0,
    }
