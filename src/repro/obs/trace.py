"""Span tracing (DESIGN.md §8): device-program phase names + a host
Chrome-trace tracer.

Two complementary layers:

* :func:`named_phase` — wraps a region of *traced* code in
  ``jax.named_scope`` so the rollout/estimate/aggregate/agree phases are
  identifiable in XLA dumps and ``jax.profiler`` captures. It is only
  applied when the config's static ``telemetry`` flag is on, because name
  metadata participates in program identity and the off path must compile
  to the exact seed program.
* :class:`Tracer` — a host-side wall-clock tracer emitting
  Chrome-trace-event JSON (``{"traceEvents": [...]}``), loadable in
  Perfetto / ``chrome://tracing``. ``engine.py`` wraps loop-cache builds
  and per-lane-group dispatches in :func:`host_span`, so the trace shows
  compile vs execute wall time per lane group and cache hits/misses.
  Host spans additionally enter ``jax.profiler.TraceAnnotation`` so they
  line up with device events when a profiler session is active.

Host spans are no-ops (a shared ``nullcontext``) while telemetry is
disabled — the hot loops must not pay for instrumentation that is off.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Optional

import jax

from repro.obs import metrics as _metrics

_NULL = contextlib.nullcontext()


def named_phase(name: str, enabled: bool = True):
    """``jax.named_scope(name)`` when ``enabled`` (a static config flag),
    else a no-op context — the off path's jaxpr keeps its historical
    name stack."""
    return jax.named_scope(name) if enabled else _NULL


class Tracer:
    """Accumulates Chrome trace events (host wall-clock, us since the
    tracer's epoch)."""

    def __init__(self):
        self.events: list = []
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Complete ("X") event around the scope; ``args`` must be
        JSON-serializable."""
        t0 = self._now_us()
        try:
            ann = jax.profiler.TraceAnnotation(name)
        except Exception:                      # profiler backend absent
            ann = contextlib.nullcontext()
        try:
            with ann:
                yield
        finally:
            self.events.append({
                "name": name, "ph": "X", "ts": t0,
                "dur": self._now_us() - t0,
                "pid": 0, "tid": 0, "args": args,
            })

    def instant(self, name: str, **args) -> None:
        self.events.append({"name": name, "ph": "i", "ts": self._now_us(),
                            "s": "p", "pid": 0, "tid": 0, "args": args})

    def clear(self) -> None:
        self.events.clear()
        self._t0 = time.perf_counter()

    def to_chrome(self, path: Optional[str] = None) -> dict:
        """The Chrome trace document; written to ``path`` when given."""
        doc = {"traceEvents": list(self.events), "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def host_span(name: str, **args):
    """Tracer span while telemetry is enabled, else a free no-op. The
    single guard the hot host paths (``engine.compiled``, ``run_grid``)
    call — one dict lookup when off."""
    if not _metrics.enabled():
        return _NULL
    return _TRACER.span(name, **args)


def host_instant(name: str, **args) -> None:
    if _metrics.enabled():
        _TRACER.instant(name, **args)


def write_trace(path: str) -> dict:
    """Write the accumulated host trace as Chrome-trace JSON."""
    return _TRACER.to_chrome(path)
