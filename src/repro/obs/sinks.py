"""Metric sinks (DESIGN.md §8): where telemetry records go.

Every record is one flat dict ``{"stream": <name>, **payload}`` produced
by the :class:`~repro.obs.metrics.Recorder`. Sinks are deliberately tiny
— ``emit(record)`` + ``close()`` — so new transports (a socket for the
multi-host sweep service, a pytest capture) are a few lines.

* :class:`MemorySink` — append to a list (tests, notebooks).
* :class:`JsonlSink` — one JSON object per line; numpy scalars/arrays are
  converted to plain Python so every line is loadable anywhere.
* :class:`StdoutProgressSink` — human-oriented progress lines, filtered
  to the ``progress`` stream by default so metric taps don't spam the
  terminal. This is the single reporting path the benchmarks/examples
  entry points print through (:func:`repro.obs.progress`).
"""
from __future__ import annotations

import json
from typing import Optional, Sequence, TextIO

import numpy as np


def _jsonable(v):
    """Plain-Python view of one payload value (numpy/jax arrays included)."""
    if isinstance(v, (str, bool, int, float, type(None))):
        return v
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


class Sink:
    """Base sink: receives every record the recorder accepts."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Collect records in ``self.records`` (tests / notebooks)."""

    def __init__(self):
        self.records: list = []

    def emit(self, record: dict) -> None:
        self.records.append(record)


class JsonlSink(Sink):
    """One JSON object per line, flushed per record (tail -f friendly)."""

    def __init__(self, path: str):
        self.path = path
        self._f: Optional[TextIO] = open(path, "w")

    def emit(self, record: dict) -> None:
        if self._f is None:
            return
        self._f.write(json.dumps(_jsonable(record)) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class StdoutProgressSink(Sink):
    """Print the ``message`` field of matching streams to stdout.

    ``streams=None`` prints every stream (debug); the default prints only
    the ``progress`` stream, so in-loop metric taps stay off the terminal.
    """

    def __init__(self, streams: Optional[Sequence[str]] = ("progress",)):
        self.streams = None if streams is None else tuple(streams)

    def emit(self, record: dict) -> None:
        if self.streams is not None and record.get("stream") \
                not in self.streams:
            return
        msg = record.get("message")
        if msg is None:
            payload = {k: v for k, v in record.items() if k != "stream"}
            msg = f"[{record.get('stream')}] {_jsonable(payload)}"
        print(msg, flush=True)
