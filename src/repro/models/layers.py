"""Shared model layers: norms, RoPE, MLPs, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, scale=None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (scale * jax.random.truncated_normal(key, -3, 3, shape)).astype(dtype)


def rms_norm(x, weight, eps, fused: bool = False):
    if fused:
        # reduce in f32 via the dot accumulator; never materialize an f32
        # copy of x (halves the saved-residual footprint in bf16 training)
        ss = jnp.einsum("...d,...d->...", x, x,
                        preferred_element_type=jnp.float32) / x.shape[-1]
        inv = jax.lax.rsqrt(ss + eps)[..., None].astype(x.dtype)
        return x * inv * weight
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """Rotate-half RoPE.

    x: (..., S, H, head_dim); positions: broadcastable to (..., S), int32.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_swiglu(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv over the sequence axis.

    x: (B, S, C); w: (K, C). Returns (y, new_state) where state is the last
    K-1 inputs, for single-step decode chaining.
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:-2] + (K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=-2)           # (B, S+K-1, C)
    S = x.shape[-2]
    y = sum(xp[..., i:i + S, :] * w[i] for i in range(K))
    new_state = xp[..., S:, :] if K > 1 else state
    return y, new_state
