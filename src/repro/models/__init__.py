from repro.models.model import (decode_step, forward, init_cache, init_params,
                                lm_loss, prefill)
