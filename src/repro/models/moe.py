"""Mixture-of-Experts with sort-based grouped dispatch.

FLOP-honest on the compiled dry-run: dispatch/combine are gathers/scatters
(zero matmul FLOPs); expert compute is a single batched einsum over
(E, capacity) buffers, so HLO_FLOPs track 6*N_active*D instead of the
T x E x C dense-dispatch blowup of mask-einsum MoE implementations.

Routing/dispatch is PER BATCH ROW (vmapped): the sort, rank and scatter
stay local to each row's tokens, so under pjit the (B, E, C, D) dispatch
buffers shard over the batch axes and the expert-weight gradients keep
their model sharding. (A global argsort over all B*S tokens forces the
SPMD partitioner to replicate the dispatch, which turns the per-layer
gradient all-reduce into a full-tensor reduction — 16x the wire at grok-1
scale; see EXPERIMENTS.md §Perf.)

Sharding: expert weights carry a leading E axis. For E >= mesh model-axis
size the experts shard over "model" (expert parallelism); for small E
(grok: 8) the per-expert ffn dim shards over "model" (tensor parallelism
within experts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_swiglu, swiglu


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff_expert), dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff_expert), dtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_ff_expert, d), dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d,
                                  m.n_shared_experts * m.d_ff_expert, dtype)
    return p


def _moe_tokens(p, cfg, xf):
    """One row's tokens. xf: (T, D) -> (y (T, D), aux scalar)."""
    m = cfg.moe
    T, D = xf.shape

    logits = (xf @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)          # (T, k)
    top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                          # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.float32).sum(1), axis=0
    ) / m.top_k
    aux = m.router_aux_weight * m.n_experts * jnp.sum(me * ce)

    # ---- sort-based grouped dispatch (local to this row) ----
    cap = int(T * m.top_k * m.capacity_factor / m.n_experts + 1)
    e_flat = top_e.reshape(-1)                            # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T), m.top_k)
    w_flat = top_w.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    # rank of each entry within its expert group
    same = jax.nn.one_hot(e_s, m.n_experts, dtype=jnp.int32)  # (T*k, E)
    rank = (jnp.cumsum(same, axis=0) * same).sum(-1) - 1      # (T*k,)
    keep = rank < cap
    slot = jnp.where(keep, e_s * cap + rank, m.n_experts * cap)
    # buffers: token index per (expert, cap) slot; pad row = T
    buf_tok = jnp.full((m.n_experts * cap + 1,), T, jnp.int32
                       ).at[slot].set(t_s.astype(jnp.int32))[:-1]
    buf_w = jnp.zeros((m.n_experts * cap + 1,), jnp.float32
                      ).at[slot].set(jnp.where(keep, w_s, 0.0))[:-1]
    buf_tok = buf_tok.reshape(m.n_experts, cap)
    buf_w = buf_w.reshape(m.n_experts, cap)

    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xpad[buf_tok]                                    # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])       # compute dtype
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, C, D)
    ye = ye * buf_w[..., None].astype(ye.dtype)

    y = jnp.zeros((T + 1, D), ye.dtype).at[buf_tok.reshape(-1)].add(
        ye.reshape(-1, D))[:T]

    if m.n_shared_experts:
        y = y + swiglu(xf, **p["shared"])
    return y, aux


def moe_forward(p, cfg, x):
    """x: (B, S, D) -> (y, aux_loss). Per-row routing (see module doc)."""
    y, aux = jax.vmap(lambda row: _moe_tokens(p, cfg, row))(x)
    return y, jnp.mean(aux)
