"""Attention: GQA (full / chunked / sliding-window) and MLA, with caches.

All functions are shape-polymorphic in batch and sequence; the decode path
uses a ring-buffer KV cache so a sliding-window variant is sub-quadratic in
both compute and memory (long_500k).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA parameter init
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def init_mla(key, cfg, dtype):
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_hd = a.qk_nope_head_dim + a.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "w_dkv": dense_init(ks[0], (d, a.kv_lora_rank + a.qk_rope_head_dim),
                            dtype),
        "w_uk": dense_init(ks[1], (a.kv_lora_rank, H * a.qk_nope_head_dim),
                           dtype),
        "w_uv": dense_init(ks[2], (a.kv_lora_rank, H * a.v_head_dim), dtype),
        "wo": dense_init(ks[3], (H * a.v_head_dim, d), dtype),
    }
    if a.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], (d, a.q_lora_rank), dtype)
        p["w_uq"] = dense_init(ks[5], (a.q_lora_rank, H * qk_hd), dtype)
    else:
        p["wq"] = dense_init(ks[4], (d, H * qk_hd), dtype)
    return p


# ---------------------------------------------------------------------------
# Core masked attention (einsum-grouped GQA: no kv repeat materialization)
# ---------------------------------------------------------------------------

def _grouped_attn(q, k, v, mask):
    """q: (B,Sq,Hkv,G,hd); k,v: (B,Sk,Hkv,hd); mask: (B,1,1,Sq,Sk) bool."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out


def chunked_causal_attention(q, k, v, q_pos, k_pos,
                             window: Optional[int] = None,
                             chunk: int = 1024):
    """Causal (optionally sliding-window) attention, scanned over q chunks.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd); q_pos: (Sq,), k_pos: (Sk,).
    Scores for one chunk are (B, H, chunk, Sk) — never Sq x Sk. KV heads are
    repeated to H (same footprint as q) so the score tensor shards over the
    full query-head dim; each chunk body is rematerialized so the backward
    never holds more than one chunk's scores.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
    n = q.shape[1] // chunk
    qc = q.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(n, chunk)
    scale = hd ** -0.5

    @jax.checkpoint
    def body(_, xs):
        qi, pi = xs                                  # (B, chunk, H, hd)
        m = pi[:, None] >= k_pos[None, :]
        if window is not None:
            m &= (pi[:, None] - k_pos[None, :]) < window
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(m[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", w, v)

    _, out = jax.lax.scan(body, None, (qc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, -1)
    return out[:, :Sq]


def cache_attention(q, k_cache, v_cache, q_pos, slot_pos,
                    window: Optional[int] = None):
    """Single-step decode attention over a ring cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, W, Hkv, hd); slot_pos: (W,) int32
    holding the absolute position stored in each slot (-1 = empty).
    """
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    qg = q.reshape(B, 1, Hkv, H // Hkv, hd)
    m = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        m &= (q_pos - slot_pos) < window
    m = m[None, None, None, None, :]                  # (1,1,1,1,W)
    out = _grouped_attn(qg, k_cache, v_cache, m)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# GQA block forward (train/prefill) and decode step
# ---------------------------------------------------------------------------

def _qkv(p, cfg, x):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def gqa_forward(p, cfg, x, positions, window=None):
    """x: (B,S,D), positions: (S,) -> (B,S,D). No cache."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_causal_attention(q, k, v, positions, positions,
                                   window=window or cfg.sliding_window)
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def gqa_decode(p, cfg, x, pos, cache_kv, slot_pos, window=None):
    """x: (B,1,D); cache_kv: dict(k=(B,W,Hkv,hd), v=...); slot_pos: (W,)
    already updated to include ``pos`` at slot ``pos % W``."""
    B = x.shape[0]
    q, k, v = _qkv(p, cfg, x)
    pos_arr = pos[None] if pos.ndim == 0 else pos
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    W = cache_kv["k"].shape[1]
    idx = pos % W
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_kv["k"], k, idx, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_kv["v"], v, idx, axis=1)
    out = cache_attention(q, new_k, new_v, pos, slot_pos,
                          window=window or cfg.sliding_window)
    return out.reshape(B, 1, -1) @ p["wo"], {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA forward / decode (latent cache; optional absorbed matmuls for decode)
# ---------------------------------------------------------------------------

def _mla_q(p, cfg, x, positions):
    a = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_hd = a.qk_nope_head_dim + a.qk_rope_head_dim
    if a.q_lora_rank:
        q = (x @ p["w_dq"]) @ p["w_uq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, qk_hd)
    q_nope = q[..., :a.qk_nope_head_dim]
    q_rope = apply_rope(q[..., a.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    a = cfg.mla
    ckv = x @ p["w_dkv"]                              # (B,S,r+rope)
    c = ckv[..., :a.kv_lora_rank]
    k_rope = apply_rope(ckv[..., None, a.kv_lora_rank:], positions,
                        cfg.rope_theta)               # (B,S,1,rope)
    return c, k_rope[..., 0, :]


def mla_forward(p, cfg, x, positions, window=None):
    a = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = (c @ p["w_uk"]).reshape(B, S, H, a.qk_nope_head_dim)
    v = (c @ p["w_uv"]).reshape(B, S, H, a.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (B, S, H, a.qk_rope_head_dim))], axis=-1)
    out = chunked_causal_attention(q, k, v, positions, positions,
                                   window=window)
    return out.reshape(B, S, -1) @ p["wo"], (c, k_rope)


def mla_decode(p, cfg, x, pos, cache, slot_pos, window=None, absorb=True):
    """Latent-cache decode. cache: dict(c=(B,W,r), k_rope=(B,W,rope)).

    absorb=True uses the DeepSeek weight-absorption identity so the per-step
    cost is O(W * (r + rope) * H) instead of expanding full K/V from the
    latent each step (see EXPERIMENTS.md §Perf).
    """
    a = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos_arr = pos[None] if pos.ndim == 0 else pos
    q_nope, q_rope = _mla_q(p, cfg, x, pos_arr)       # (B,1,H,*)
    c_t, kr_t = _mla_latent(p, cfg, x, pos_arr)
    W = cache["c"].shape[1]
    idx = pos % W
    c = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_t, idx, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_t, idx,
                                                 axis=1)
    m = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        m &= (pos - slot_pos) < window

    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    if absorb:
        w_uk = p["w_uk"].reshape(a.kv_lora_rank, H, a.qk_nope_head_dim)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        s = jnp.einsum("bqhr,bkr->bhqk", q_lat, c,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
        s = jnp.where(m[None, None, None, :], s * scale, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(c.dtype)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", w, c)
        w_uv = p["w_uv"].reshape(a.kv_lora_rank, H, a.v_head_dim)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv)
    else:
        k_nope = (c @ p["w_uk"]).reshape(B, W, H, a.qk_nope_head_dim)
        v = (c @ p["w_uv"]).reshape(B, W, H, a.v_head_dim)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
        s = jnp.where(m[None, None, None, :], s * scale, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"c": c, "k_rope": k_rope}
