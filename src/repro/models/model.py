"""Composable decoder model: init / forward / prefill / decode for all
assigned architecture families, with scan-over-layers stacked parameters.

Families
--------
dense / vlm / audio : [norm -> GQA|MLA -> +res -> norm -> SwiGLU -> +res]
moe                 : as dense but MLP is the sort-dispatch MoE
hybrid (hymba)      : [norm -> (attn + mamba)/2 -> +res -> norm -> SwiGLU -> +res]
ssm (xlstm)         : alternating [mLSTM, sLSTM] residual blocks, no FFN

Caches: attention layers use a ring-buffer KV (or MLA latent) cache whose
size *is* the attention window — long_500k decode simply allocates a
``long_context_window``-sized ring. Recurrent layers carry O(1) state.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dense_init, init_swiglu, rms_norm, swiglu


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, dtype):
    """One decoder block's parameters (unstacked)."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"norm_attn": jnp.ones((d,), dtype),
         "norm_mlp": jnp.ones((d,), dtype)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    if cfg.moe is not None:
        p["mlp"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_swiglu(ks[1], d, cfg.d_ff, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_lib.init_mamba(ks[2], cfg, dtype)
    return p


def _init_xlstm_pair(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "m": ssm_lib.init_mlstm(k1, cfg, dtype),
        "s": ssm_lib.init_slstm(k2, cfg, dtype),
        "norm_m": jnp.ones((d,), dtype),
        "norm_s": jnp.ones((d,), dtype),
    }


def n_block_stacks(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return cfg.n_layers // cfg.xlstm.slstm_every
    return cfg.n_layers


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    k_emb, k_blocks, k_head, k_proj = jax.random.split(key, 4)
    d = cfg.d_model
    params = {
        "embed": 0.02 * jax.random.normal(k_emb, (cfg.vocab_size, d), dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    nb = n_block_stacks(cfg)
    init_one = (functools.partial(_init_xlstm_pair, cfg, dtype=dtype)
                if cfg.family == "ssm"
                else functools.partial(_init_block, cfg, dtype=dtype))
    params["blocks"] = jax.vmap(init_one)(jax.random.split(k_blocks, nb))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (d, cfg.vocab_size), dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(k_proj, (d, d), dtype)
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, tokens, prefix_embeds=None):
    """tokens: (B, S_text) int32; prefix_embeds: (B, P, D) or None."""
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_logits(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ w


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_seq(cfg, p, x, positions, window):
    """One block over a full sequence. Returns (x, cache_parts, aux)."""
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps, cfg.fused_rmsnorm)
    if cfg.mla is not None:
        a_out, kv = attn.mla_forward(p["attn"], cfg, h, positions,
                                     window=window)
        cache = {"c": kv[0], "k_rope": kv[1]}
    else:
        a_out, kv = attn.gqa_forward(p["attn"], cfg, h, positions,
                                     window=window)
        cache = {"k": kv[0], "v": kv[1]}
    if cfg.family == "hybrid":
        s_out, s_state = ssm_lib.mamba_forward(p["ssm"], cfg, h)
        a_out = (a_out + s_out) * 0.5
        cache = {"kv": cache, "ssm": s_state}
    else:
        cache = {"kv": cache}
    x = x + a_out
    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps, cfg.fused_rmsnorm)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        m_out, aux = moe_lib.moe_forward(p["mlp"], cfg, h)
    else:
        m_out = swiglu(h, **p["mlp"])
    return x + m_out, cache, aux


def _xlstm_pair_seq(cfg, p, x, state=None):
    sm = None if state is None else state["m"]
    ss = None if state is None else state["s"]
    h, new_m = ssm_lib.mlstm_forward(
        p["m"], cfg, rms_norm(x, p["norm_m"], cfg.norm_eps, cfg.fused_rmsnorm), sm)
    x = x + h
    h, new_s = ssm_lib.slstm_forward(
        p["s"], cfg, rms_norm(x, p["norm_s"], cfg.norm_eps, cfg.fused_rmsnorm), ss)
    return x + h, {"m": new_m, "s": new_s}


def forward(cfg: ModelConfig, params, tokens=None, prefix_embeds=None,
            positions=None, window: Optional[int] = None,
            collect_cache: bool = False, remat: bool = True,
            last_only: bool = False):
    """Full-sequence forward. Returns (logits, aux, cache_parts|None).

    cache_parts has per-layer leading axis (stacked by the layer scan).
    ``remat`` checkpoints each layer in the scan (recompute in backward) —
    without it the attention backward stores O(S²) softmax weights per layer.
    """
    x = embed_inputs(cfg, params, tokens, prefix_embeds)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.family == "ssm":
        def body(carry, bp):
            h, _ = carry
            h, st = _xlstm_pair_seq(cfg, bp, h)
            return (h, jnp.zeros((), jnp.float32)), st
        if remat:
            body = jax.checkpoint(body)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])
    else:
        def body(carry, bp):
            h, aux = carry
            h, cache, a = _block_seq(cfg, bp, h, positions, window)
            return (h, aux + a), cache if collect_cache else None
        if remat:
            body = jax.checkpoint(body)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])

    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.fused_rmsnorm)
    logits = lm_logits(cfg, params, x)
    return logits, aux, caches


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.float32):
    """Empty decode cache. ``cache_len`` is the KV ring size (= the attention
    window when smaller than the total sequence)."""
    nb = n_block_stacks(cfg)

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (nb,) + x.shape),
                            tree)

    cache = {"pos": jnp.zeros((), jnp.int32),
             "slot_pos": jnp.full((cache_len,), -1, jnp.int32)}
    if cfg.family == "ssm":
        cache["blocks"] = stack({
            "m": ssm_lib.init_mlstm_state(cfg, batch, dtype),
            "s": ssm_lib.init_slstm_state(cfg, batch, dtype)})
        return cache
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        kv = {"c": jnp.zeros((batch, cache_len, cfg.mla.kv_lora_rank), dtype),
              "k_rope": jnp.zeros((batch, cache_len,
                                   cfg.mla.qk_rope_head_dim), dtype)}
    else:
        kv = {"k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
              "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype)}
    block_cache = {"kv": kv}
    if cfg.family == "hybrid":
        block_cache["ssm"] = ssm_lib.init_mamba_state(cfg, batch, dtype)
    cache["blocks"] = stack(block_cache)
    return cache


def prefill(cfg: ModelConfig, params, tokens=None, prefix_embeds=None,
            cache_len: Optional[int] = None, window: Optional[int] = None,
            last_only: bool = True):
    """Run the prompt, build the decode cache. Returns (logits, cache)."""
    logits, _, caches = forward(cfg, params, tokens, prefix_embeds,
                                window=window, collect_cache=True,
                                last_only=last_only)
    S = (tokens.shape[1] if tokens is not None else 0) + \
        (prefix_embeds.shape[1] if prefix_embeds is not None else 0)
    cache_len = cache_len or S

    if cfg.family == "ssm":
        return logits, {"pos": jnp.asarray(S, jnp.int32),
                        "slot_pos": jnp.zeros((cache_len,), jnp.int32),
                        "blocks": caches}

    def fit(x):
        # seq axis is axis=2 of the stacked (L, B, S, ...) kv tensors
        if x.ndim >= 3 and x.shape[2] == S:
            x = x[:, :, -cache_len:] if S >= cache_len else jnp.pad(
                x, [(0, 0), (0, 0), (0, cache_len - S)]
                + [(0, 0)] * (x.ndim - 3))
        return x

    blocks = {}
    kv = jax.tree.map(fit, caches["kv"])
    blocks["kv"] = kv
    if cfg.family == "hybrid":
        blocks["ssm"] = caches["ssm"]
    keep = min(S, cache_len)
    slot_pos = jnp.full((cache_len,), -1, jnp.int32)
    slot_pos = slot_pos.at[:keep].set(jnp.arange(S - keep, S))
    # ring alignment: continue writing at pos % cache_len; after prefill the
    # next write index is S % cache_len, which must be the oldest slot.
    # Roll stored entries so that slot (pos % W) is the oldest.
    if keep == cache_len:
        shift = 0  # slots 0..W-1 hold positions S-W..S-1; next idx = S % W
        roll = (S % cache_len)
        kv = jax.tree.map(lambda x: jnp.roll(x, roll, axis=2), kv)
        slot_pos = jnp.roll(slot_pos, roll)
        blocks["kv"] = kv
        del shift
    return logits, {"pos": jnp.asarray(S, jnp.int32), "slot_pos": slot_pos,
                    "blocks": blocks}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _block_decode(cfg, p, x, pos, slot_pos, cache):
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps, cfg.fused_rmsnorm)
    if cfg.mla is not None:
        a_out, new_kv = attn.mla_decode(p["attn"], cfg, h, pos, cache["kv"],
                                        slot_pos, absorb=cfg.mla_absorb)
    else:
        a_out, new_kv = attn.gqa_decode(p["attn"], cfg, h, pos, cache["kv"],
                                        slot_pos)
    new_cache = {"kv": new_kv}
    if cfg.family == "hybrid":
        s_out, new_ssm = ssm_lib.mamba_decode(p["ssm"], cfg, h, cache["ssm"])
        a_out = (a_out + s_out) * 0.5
        new_cache["ssm"] = new_ssm
    x = x + a_out
    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps, cfg.fused_rmsnorm)
    if cfg.moe is not None:
        m_out, _ = moe_lib.moe_forward(p["mlp"], cfg, h)
    else:
        m_out = swiglu(h, **p["mlp"])
    return x + m_out, new_cache


def decode_step(cfg: ModelConfig, params, token, cache):
    """token: (B,) or (B,1) int32. Returns (logits (B,1,V), new cache)."""
    if token.ndim == 1:
        token = token[:, None]
    x = params["embed"][token]
    pos = cache["pos"]

    if cfg.family == "ssm":
        def body(h, xs):
            bp, bc = xs
            hh, st_m = ssm_lib.mlstm_forward(
                bp["m"], cfg, rms_norm(h, bp["norm_m"], cfg.norm_eps, cfg.fused_rmsnorm),
                bc["m"])
            h = h + hh
            hh, st_s = ssm_lib.slstm_forward(
                bp["s"], cfg, rms_norm(h, bp["norm_s"], cfg.norm_eps, cfg.fused_rmsnorm),
                bc["s"])
            return h + hh, {"m": st_m, "s": st_s}
        x, new_blocks = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {"pos": pos + 1, "slot_pos": cache["slot_pos"],
                     "blocks": new_blocks}
    else:
        W = cache["slot_pos"].shape[0]
        slot_pos = cache["slot_pos"].at[pos % W].set(pos)

        def body(h, xs):
            bp, bc = xs
            return _block_decode(cfg, bp, h, pos, slot_pos, bc)
        x, new_blocks = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {"pos": pos + 1, "slot_pos": slot_pos,
                     "blocks": new_blocks}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.fused_rmsnorm)
    return lm_logits(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# Per-slot decode (continuous-batching serving)
# ---------------------------------------------------------------------------

def init_slot_cache(cfg: ModelConfig, slots: int, cache_len: int,
                    dtype=jnp.float32):
    """Empty per-slot decode cache: like :func:`init_cache` but every batch
    row is an independent serving slot with its own write position —
    ``pos`` is ``(slots,)`` and ``slot_pos`` is ``(slots, cache_len)``.
    All slots start empty (``slot_pos = -1``)."""
    cache = init_cache(cfg, slots, cache_len, dtype)
    return {"pos": jnp.zeros((slots,), jnp.int32),
            "slot_pos": jnp.full((slots, cache_len), -1, jnp.int32),
            "blocks": cache["blocks"]}


def decode_step_slots(cfg: ModelConfig, params, tokens, cache):
    """One decode step over a per-slot cache (:func:`init_slot_cache`).

    ``tokens``: ``(slots,)`` int32 — each slot advances at its *own*
    position; rows are vmapped through :func:`decode_step` so a slot's
    logits depend only on its own ring contents (the batching-invariance
    contract the serving tests pin). Returns ``(logits (slots, V),
    new cache)``."""
    def one(tok, pos, slot_pos, blocks):
        row = {"pos": pos, "slot_pos": slot_pos,
               "blocks": jax.tree.map(lambda x: x[:, None], blocks)}
        logits, new = decode_step(cfg, params, tok[None], row)
        return (logits[0, 0], new["pos"], new["slot_pos"],
                jax.tree.map(lambda x: x[:, 0], new["blocks"]))

    logits, pos, slot_pos, blocks = jax.vmap(
        one, in_axes=(0, 0, 0, 1), out_axes=(0, 0, 0, 1))(
            tokens, cache["pos"], cache["slot_pos"], cache["blocks"])
    return logits, {"pos": pos, "slot_pos": slot_pos, "blocks": blocks}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _cross_entropy(logits, labels):
    """Sharding-friendly CE: reductions over the (vocab-sharded) last axis
    only — never gathers logits (the take_along_axis formulation forces an
    all-gather of vocab-parallel logits under GSPMD)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    correct = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits,
                                0.0), axis=-1)
    return jnp.mean(lse - correct)


def lm_loss_labeled(cfg: ModelConfig, params, tokens, labels,
                    prefix_embeds=None):
    """CE of logits at every token position vs. given labels (+ MoE aux).
    Processes exactly ``tokens.shape[1] (+ prefix)`` positions."""
    logits, aux, _ = forward(cfg, params, tokens, prefix_embeds)
    P = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    return _cross_entropy(logits[:, P:], labels) + aux


def lm_loss(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    """Next-token cross-entropy (+ MoE aux). tokens: (B, S_text)."""
    logits, aux, _ = forward(cfg, params, tokens[:, :-1], prefix_embeds)
    P = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    return _cross_entropy(logits[:, P:], tokens[:, 1:]) + aux
