"""Recurrent blocks: Mamba-style selective SSM, mLSTM, sLSTM.

Each block provides (a) a sequence forward via ``lax.scan`` over time used by
train/prefill, and (b) a single-step decode update over a small carried
state — this is what makes long_500k decode O(1) per token for the
ssm/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init


def time_scan(step, carry, xs, chunk: int = 0):
    """lax.scan over time with optional chunked rematerialization.

    With ``chunk > 0`` the scan runs as an outer loop over S/chunk blocks
    whose bodies are ``jax.checkpoint``-ed inner scans: the backward pass
    stores the recurrent carry only at chunk boundaries and recomputes
    within a chunk (O(S/chunk) instead of O(S) state snapshots — the
    dominant training-memory term for mLSTM's matrix memory).
    xs leaves are time-major: (S, ...).
    """
    if chunk <= 0:
        return jax.lax.scan(step, carry, xs)
    S = jax.tree.leaves(xs)[0].shape[0]
    if S % chunk or S <= chunk:
        return jax.lax.scan(step, carry, xs)
    n = S // chunk
    xs_c = jax.tree.map(
        lambda l: l.reshape((n, chunk) + l.shape[1:]), xs)

    @jax.checkpoint
    def outer(c, xc):
        return jax.lax.scan(step, c, xc)

    carry, ys = jax.lax.scan(outer, carry, xs_c)
    ys = jax.tree.map(lambda l: l.reshape((S,) + l.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (used standalone and inside hybrid blocks)
# ---------------------------------------------------------------------------

def _dt_rank(cfg):
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (s.conv_dim, d_in), dtype, scale=0.5),
        "w_xdb": dense_init(ks[2], (d_in, dtr + 2 * s.state_dim), dtype),
        "w_dt": dense_init(ks[3], (dtr, d_in), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (d_in,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.state_dim + 1, dtype=jnp.float32),
            (d_in, 1))).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[6], (d_in, d), dtype),
    }


def _mamba_inner(p, cfg, xz, conv_state):
    """xz: (B, S, 2*d_in) pre-projected. Returns gatable y and new conv state."""
    s = cfg.ssm
    d_in = xz.shape[-1] // 2
    x, z = jnp.split(xz, 2, axis=-1)
    x, new_conv = causal_conv1d(x, p["conv_w"], conv_state)
    x = jax.nn.silu(x)
    xdb = x @ p["w_xdb"]
    dtr = _dt_rank(cfg)
    dt = jax.nn.softplus(xdb[..., :dtr] @ p["w_dt"]
                         + p["dt_bias"]).astype(jnp.float32)   # (B,S,d_in)
    Bm = xdb[..., dtr:dtr + s.state_dim].astype(jnp.float32)   # (B,S,N)
    Cm = xdb[..., dtr + s.state_dim:].astype(jnp.float32)      # (B,S,N)
    A = -jnp.exp(p["A_log"])                                   # (d_in,N)
    return x, z, dt, Bm, Cm, A, new_conv


def mamba_forward(p, cfg, x, state=None):
    """x: (B,S,D) -> (y, (ssm_state, conv_state))."""
    B, S, D = x.shape
    s = cfg.ssm
    d_in = s.expand * D
    xz = x @ p["w_in"]
    conv_state = None if state is None else state["conv"]
    h0 = (jnp.zeros((B, d_in, s.state_dim), jnp.float32)
          if state is None else state["h"])
    xc, z, dt, Bm, Cm, A, new_conv = _mamba_inner(p, cfg, xz, conv_state)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                       # (B,d_in),(B,d_in),(B,N)x2
        dA = jnp.exp(dt_t[..., None] * A)               # (B,d_in,N)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (xc.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2))
    h, ys = time_scan(step, h0, xs, chunk=cfg.recurrent_chunk)
    y = ys.transpose(1, 0, 2) + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"h": h, "conv": new_conv}


def mamba_decode(p, cfg, x, state):
    """x: (B,1,D); state: {'h': (B,d_in,N), 'conv': (B,K-1,d_in)}."""
    y, new_state = mamba_forward(p, cfg, x, state)
    return y, new_state


def init_mamba_state(cfg, batch, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {"h": jnp.zeros((batch, d_in, s.state_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_dim - 1, d_in), dtype)}


# ---------------------------------------------------------------------------
# mLSTM (matrix memory) — xLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype):
    x = cfg.xlstm
    d = cfg.d_model
    d_in = int(d * x.proj_factor)
    hd = d_in // cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (x.conv_dim, d_in), dtype, scale=0.5),
        "wq": dense_init(ks[2], (d_in, d_in), dtype),
        "wk": dense_init(ks[3], (d_in, d_in), dtype),
        "wv": dense_init(ks[4], (d_in, d_in), dtype),
        "w_if": dense_init(ks[5], (d_in, 2 * cfg.n_heads), dtype),
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,)),
                                 3.0 * jnp.ones((cfg.n_heads,))]).astype(dtype),
        "w_o": dense_init(ks[6], (d_in, d_in), dtype),
        "w_down": dense_init(ks[7], (d_in, d), dtype),
    }


def mlstm_forward(p, cfg, x, state=None):
    """x: (B,S,D) -> (y, state). Matrix memory per head: C (B,H,hd,hd)."""
    B, S, D = x.shape
    H = cfg.n_heads
    d_in = p["wq"].shape[0]
    hd = d_in // H
    up = x @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    uc, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    uc = jax.nn.silu(uc)
    # q/k/v stay in the compute dtype; the scan upcasts per step, so the
    # saved per-chunk inputs are bf16 instead of f32 (halves xs stacks)
    q = (uc @ p["wq"]).reshape(B, S, H, hd)
    k = (uc @ p["wk"]).reshape(B, S, H, hd) * hd ** -0.5
    v = (uc @ p["wv"]).reshape(B, S, H, hd)
    gates = (uc @ p["w_if"] + p["b_if"]).astype(jnp.float32)  # (B,S,2H)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li, lf = inp
        q_t, k_t, v_t = (a.astype(jnp.float32) for a in (q_t, k_t, v_t))
        m_new = jnp.maximum(lf + m, li)                  # (B,H)
        f_ = jnp.exp(lf + m - m_new)[..., None, None]
        i_ = jnp.exp(li - m_new)[..., None, None]
        C = f_ * C + i_ * (v_t[..., :, None] * k_t[..., None, :])
        n = f_[..., 0] * n + i_[..., 0] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + \
        (log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
    (C, n, m), hs = time_scan(step, (C0, n0, m0), xs,
                              chunk=cfg.recurrent_chunk)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in).astype(x.dtype)
    h = (h @ p["w_o"]) * jax.nn.silu(z)
    y = h @ p["w_down"]
    return y, {"C": C, "n": n, "m": m, "conv": new_conv}


def init_mlstm_state(cfg, batch, dtype):
    x = cfg.xlstm
    d_in = int(cfg.d_model * x.proj_factor)
    hd = d_in // cfg.n_heads
    return {"C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
            "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, x.conv_dim - 1, d_in), dtype)}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory) — xLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_x": dense_init(ks[0], (d, 4 * d), dtype),
        "r_h": dense_init(ks[1], (d, 4 * d), dtype, scale=d ** -0.5 * 0.5),
        "b": jnp.zeros((4 * d,), dtype),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def slstm_forward(p, cfg, x, state=None):
    """x: (B,S,D) -> (y, state). Exponential gating with stabilizer."""
    B, S, D = x.shape
    if state is None:
        h0 = jnp.zeros((B, D), jnp.float32)
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        m0 = jnp.zeros((B, D), jnp.float32)
    else:
        h0, c0, n0, m0 = (state["h"], state["c"], state["n"], state["m"])
    xw = (x @ p["w_x"] + p["b"]).astype(jnp.float32)

    def step(carry, xw_t):
        h, c, n, m = carry
        pre = xw_t + (h.astype(x.dtype) @ p["r_h"]).astype(jnp.float32)
        zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(log_f + m, zi)
        i_ = jnp.exp(zi - m_new)
        f_ = jnp.exp(log_f + m - m_new)
        c = f_ * c + i_ * jnp.tanh(zz)
        n = f_ * n + i_
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    (h, c, n, m), hs = time_scan(step, (h0, c0, n0, m0),
                                 xw.transpose(1, 0, 2),
                                 chunk=cfg.recurrent_chunk)
    y = hs.transpose(1, 0, 2).astype(x.dtype) @ p["w_out"]
    return y, {"h": h, "c": c, "n": n, "m": m}


def init_slstm_state(cfg, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones((batch, d), jnp.float32), "m": z}
