"""Serving driver: batched prefill + greedy decode (deliverable (b)).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models.model import decode_step, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = None
    if cfg.frontend != "none":
        pe = jax.random.normal(key, (B, cfg.n_prefix_embeds, cfg.d_model))

    cache_len = S + cfg.n_prefix_embeds + args.gen
    prefill_jit = jax.jit(lambda p, t, e: prefill(
        cfg, p, t, e, cache_len=cache_len))
    decode_jit = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))

    t0 = time.time()
    logits, cache = prefill_jit(params, prompts, pe)
    tok = jnp.argmax(logits[:, -1], axis=-1)
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode_jit(params, tok, cache)
        tok = jnp.argmax(logits[:, 0], axis=-1)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.2f} ms/token")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {list(map(int, gen[b][:12]))}")


if __name__ == "__main__":
    main()
