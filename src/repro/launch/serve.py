"""Serving driver: continuous-batching decode CLI (deliverable (b)).

Two modes share the `repro.serving` engine:

* **LM traffic** (default) — any registered arch (reduced or full),
  token-prompt requests over its vocab:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --reduced --requests 32 --slots 4 --gen 16

* **policy traffic** (``--policy``) — the aggregated transformer policy
  via the ``repro.serving.serve`` front door (observation requests
  through the prefix-embedding frontend):

    PYTHONPATH=src python -m repro.launch.serve \
        --policy "transformer(arch='llama3.2-1b', n_layers=2, \
d_model=64, n_heads=2)" --checkpoint results/policy.npz
"""
import argparse

import jax

from repro import obs
from repro.configs.base import get_config, reduced
from repro.models.model import init_params
from repro.serving import DecodeEngine, PolicyServer, make_traffic, serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default=None,
                    help="policy spec string — serve observation traffic "
                         "through repro.serving.serve instead of LM "
                         "token traffic")
    ap.add_argument("--env", default="cartpole(horizon=32)")
    ap.add_argument("--checkpoint", default=None,
                    help="aggregated-policy checkpoint (policy mode)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--offline", action="store_true")
    args = ap.parse_args()

    if args.policy is not None:
        kw = {"checkpoint": args.checkpoint} if args.checkpoint else \
            {"key": jax.random.PRNGKey(args.seed)}
        report = serve(policy=args.policy, env=args.env,
                       n_requests=args.requests, rate_rps=args.rate,
                       slots=args.slots, max_new=args.gen,
                       seed=args.seed, realtime=not args.offline, **kw)
        obs.progress("policy serve", **report.summary())
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key_init, _ = jax.random.split(jax.random.PRNGKey(args.seed))
    params = init_params(cfg, key_init)
    engine = DecodeEngine(cfg, params, slots=args.slots, max_new=args.gen,
                          max_prompt=args.prompt_len)
    server = PolicyServer(engine)
    traffic = make_traffic(
        args.requests, seed=args.seed, rate_rps=args.rate,
        max_new=args.gen, vocab=cfg.vocab_size,
        prompt_lens=tuple(p for p in (1, 4, 8, args.prompt_len)
                          if p <= args.prompt_len))
    report = server.run_offline(traffic) if args.offline \
        else server.run(traffic)
    obs.progress(f"lm serve arch={cfg.name}", **report.summary())
    for r in report.results[:2]:
        obs.progress(f"  uid={r.uid}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
