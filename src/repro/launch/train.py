"""End-to-end federated training driver (deliverable (b)).

Runs DecByzPG over any ``--arch`` with the synthetic token pipeline:
Common-Sample PAGE coin -> per-agent gradients -> Byzantine attack (opt.)
-> robust aggregation -> per-agent Adam -> Avg-Agree_κ.

Steps execute through the fused experiment engine (DESIGN.md §2): windows
of ``--window`` iterations run as one ``lax.scan`` program with the PAGE
coin drawn in-scan, so the host only touches the device once per window.
``--no-fused`` falls back to the legacy per-step dispatch driver (two
compiled programs selected by the host-side coin).

CPU-runnable with ``--reduced`` (the 2-layer family variant); on a real
cluster drop ``--reduced`` and launch one process per host with the
production mesh.

Telemetry (DESIGN.md §8): ``--telemetry-out DIR`` turns on the obs layer —
``fed.telemetry=True`` in-step taps streamed to ``DIR/metrics.jsonl`` plus
a ``DIR/manifest.json`` run manifest; ``--profile`` additionally writes a
Chrome-trace ``DIR/trace.json`` (Perfetto/chrome://tracing-loadable) of
the host-side window spans and engine compiles.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --agents 4 --steps 30 --byz 1 --attack large_noise
"""
import argparse
import contextlib
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint import save
from repro.configs.base import get_config, reduced
from repro.core import engine
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.fed_trainer import (FedConfig, common_sample_coin,
                                           fed_train_step, fed_train_window,
                                           init_fed_state)


def _stack_batches(batches: list) -> dict:
    """List of per-step batch dicts -> one tree with a leading W axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--byz", type=int, default=0)
    ap.add_argument("--attack", default="none",
                    help="attack spec, e.g. none | large_noise(sigma=10)")
    ap.add_argument("--aggregator", default="rfa",
                    help="aggregator spec, e.g. rfa | rfa(n_iter=16)")
    ap.add_argument("--optimizer", default="adam",
                    help="optimizer spec, e.g. adam | sgd(momentum=0.9)")
    ap.add_argument("--kappa", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--page-p", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--window", type=int, default=5,
                    help="steps fused into one scanned device program")
    ap.add_argument("--no-fused", action="store_true",
                    help="legacy per-step dispatch (two compiled programs)")
    ap.add_argument("--telemetry-out", default=None, metavar="DIR",
                    help="enable telemetry; write metrics.jsonl + "
                         "manifest.json (and trace.json with --profile) "
                         "under DIR")
    ap.add_argument("--profile", action="store_true",
                    help="host span tracing -> Chrome-trace trace.json "
                         "(implies telemetry; default DIR: telemetry/)")
    args = ap.parse_args()

    out_dir = args.telemetry_out or ("telemetry" if args.profile else None)
    telemetry_on = out_dir is not None

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    # CLI strings are component specs; FedConfig normalizes them to frozen
    # Spec values resolved through the registry inside the train step.
    fed = FedConfig(aggregator=args.aggregator, kappa=args.kappa,
                    n_byz=args.byz, attack=args.attack, lr=args.lr,
                    optimizer=args.optimizer,
                    page_p=args.page_p, seed=args.seed,
                    telemetry=telemetry_on)
    K = args.agents
    key = jax.random.PRNGKey(args.seed)
    state = init_fed_state(cfg, fed, K, key)

    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        per_agent_batch=args.batch, n_agents=K,
        n_prefix_embeds=cfg.n_prefix_embeds if cfg.frontend != "none" else 0,
        d_model=cfg.d_model, seed=args.seed))
    byz_mask = jnp.asarray(np.arange(K) < args.byz)

    if telemetry_on:
        os.makedirs(out_dir, exist_ok=True)
        obs.get_tracer().clear()
        tele = obs.telemetry(
            obs.JsonlSink(os.path.join(out_dir, "metrics.jsonl")))
    else:
        tele = contextlib.nullcontext()

    obs.progress(
        f"arch={cfg.name} K={K} byz={args.byz} attack={fed.attack} "
        f"agg={fed.aggregator} opt={fed.optimizer} kappa={args.kappa} "
        f"mode={'legacy' if args.no_fused else 'fused'}")
    t0 = time.time()

    def report(step_i, coin, metrics):
        obs.progress(f"step {step_i:4d} c={int(coin)} "
                     f"loss={float(metrics['loss']):.4f} "
                     f"diam={float(metrics['diameter']):.3e} "
                     f"({time.time() - t0:.1f}s)", step=step_i)

    with tele:
        if args.no_fused:
            steps = {True: jax.jit(lambda s, b, m, k: fed_train_step(
                         cfg, fed, s, b, m, k, large=True)),
                     False: jax.jit(lambda s, b, m, k: fed_train_step(
                         cfg, fed, s, b, m, k, large=False))}
            for step_i in range(args.steps):
                c = common_sample_coin(step_i, args.seed, fed.page_p)
                key, k_step = jax.random.split(key)
                with obs.host_span("train.step", step=step_i, coin=int(c)):
                    state, metrics = steps[c](state, pipe.batch(step_i),
                                              byz_mask, k_step)
                if step_i % max(args.steps // 10, 1) == 0 \
                        or step_i == args.steps - 1:
                    report(step_i, c, metrics)
        else:
            wstep = jax.jit(
                lambda s, b, ts, k: fed_train_window(cfg, fed, s, b,
                                                     byz_mask, ts, k),
                donate_argnums=engine.donate_args(0))
            key, k_loop = jax.random.split(key)
            n_windows = -(-args.steps // args.window)
            report_every = max(n_windows // 10, 1)
            for w_i, w0 in enumerate(range(0, args.steps, args.window)):
                ts = np.arange(w0, min(w0 + args.window, args.steps))
                batches = _stack_batches([pipe.batch(int(t)) for t in ts])
                with obs.host_span("train.window", window=w_i,
                                   steps=len(ts)):
                    state, metrics = jax.block_until_ready(
                        wstep(state, batches, jnp.asarray(ts), k_loop))
                if w_i % report_every == 0 or w_i == n_windows - 1:
                    last = jax.tree.map(lambda m: m[-1], metrics)
                    report(int(ts[-1]),
                           bool(np.asarray(metrics["coin"][-1])), last)

        if args.ckpt:
            save(jax.tree.map(lambda l: l[0], state.params), args.ckpt)
            obs.progress(f"saved honest-agent-0 params to {args.ckpt}")

        if telemetry_on:
            obs.write_manifest(
                os.path.join(out_dir, "manifest.json"),
                extra={"arch": cfg.name, "K": K, "n_byz": args.byz,
                       "attack": str(fed.attack),
                       "aggregator": str(fed.aggregator),
                       "steps": args.steps, "window": args.window,
                       "mode": "legacy" if args.no_fused else "fused"})
            if args.profile:
                obs.write_trace(os.path.join(out_dir, "trace.json"))
            obs.progress(f"telemetry written to {out_dir}/")


if __name__ == "__main__":
    main()
