"""End-to-end federated training driver (deliverable (b)).

Runs DecByzPG over any ``--arch`` with the synthetic token pipeline:
Common-Sample PAGE coin -> per-agent gradients -> Byzantine attack (opt.)
-> robust aggregation -> per-agent Adam -> Avg-Agree_κ.

CPU-runnable with ``--reduced`` (the 2-layer family variant); on a real
cluster drop ``--reduced`` and launch one process per host with the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --agents 4 --steps 30 --byz 1 --attack large_noise
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import base as config_base
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.fed_trainer import (FedConfig, common_sample_coin,
                                           fed_train_step, init_fed_state)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--byz", type=int, default=0)
    ap.add_argument("--attack", default="none")
    ap.add_argument("--aggregator", default="rfa")
    ap.add_argument("--kappa", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--page-p", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    fed = FedConfig(aggregator=args.aggregator, kappa=args.kappa,
                    n_byz=args.byz, attack=args.attack, lr=args.lr,
                    page_p=args.page_p, seed=args.seed)
    K = args.agents
    key = jax.random.PRNGKey(args.seed)
    state = init_fed_state(cfg, fed, K, key)

    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        per_agent_batch=args.batch, n_agents=K,
        n_prefix_embeds=cfg.n_prefix_embeds if cfg.frontend != "none" else 0,
        d_model=cfg.d_model, seed=args.seed))
    byz_mask = jnp.asarray(np.arange(K) < args.byz)

    steps = {True: jax.jit(lambda s, b, m, k: fed_train_step(
                 cfg, fed, s, b, m, k, large=True)),
             False: jax.jit(lambda s, b, m, k: fed_train_step(
                 cfg, fed, s, b, m, k, large=False))}

    print(f"arch={cfg.name} K={K} byz={args.byz} attack={args.attack} "
          f"agg={args.aggregator} kappa={args.kappa}")
    t0 = time.time()
    for step_i in range(args.steps):
        c = common_sample_coin(step_i, args.seed, fed.page_p)
        key, k_step = jax.random.split(key)
        batch = pipe.batch(step_i)
        state, metrics = steps[c](state, batch, byz_mask, k_step)
        if step_i % max(args.steps // 10, 1) == 0 or step_i == args.steps - 1:
            print(f"step {step_i:4d} c={int(c)} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"diam={float(metrics['diameter']):.3e} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    if args.ckpt:
        save(jax.tree.map(lambda l: l[0], state.params), args.ckpt)
        print(f"saved honest-agent-0 params to {args.ckpt}")


if __name__ == "__main__":
    main()
