"""Resumable sweep CLI over the windowed sweep service (DESIGN.md §12).

Runs a DecByzPG/ByzPG scenario grid as a long-running, resumable job:
T is chunked into ``--windows`` windows, per-window carries land under
``--out`` next to the sweep manifest, and a re-launch with ``--resume``
(or the same ``--out``) continues from the last committed window —
completed lane groups are reloaded without compiling anything.

Axes sweep any config field: repeat ``--axis name=v1,v2,...`` (values
parsed as int/float when they look like numbers, component spec strings
otherwise); ``--set name=value`` pins base config fields the same way.

Multi-host: launch one process per host with ``--processes N
--process-id I --coordinator HOST:PORT`` — the flattened lane×seed
batch then spans every process's devices on one lane mesh (CPU backends
use the gloo transport, selected automatically); ``--mode shard``
instead assigns whole lane groups to processes (greedy LPT) and merges
results through the shared ``--out`` directory.

  PYTHONPATH=src python -m repro.launch.sweep --algo decbyzpg \
      --env "cartpole(horizon=100)" --T 60 --seeds 3 --windows 4 \
      --axis "eta=5e-3,1e-2" --axis "attack=none,large_noise(sigma=10)" \
      --set K=5 --set n_byz=1 --out sweeps/fig2
  # preempted? pick it up again:
  PYTHONPATH=src python -m repro.launch.sweep --resume sweeps/fig2
"""
import argparse
import ast
import contextlib
import os

from repro import obs
from repro.sweep import SweepRunner


def _parse_value(text: str):
    """CLI value -> int/float/bool/tuple when it parses, spec string
    otherwise (``hidden=(8,8)`` becomes a real tuple; ``rfa(nu=1e-3)``
    stays a string for the component registry)."""
    low = text.strip()
    if low in ("true", "True"):
        return True
    if low in ("false", "False"):
        return False
    for cast in (int, float):
        try:
            return cast(low)
        except ValueError:
            pass
    if low.startswith("("):
        try:
            val = ast.literal_eval(low)
            if isinstance(val, tuple):
                return val
        except (ValueError, SyntaxError):
            pass
    return low


def _parse_assign(text: str, flag: str):
    if "=" not in text:
        raise SystemExit(f"{flag} expects name=value, got {text!r}")
    name, _, value = text.partition("=")
    return name.strip(), value


def main() -> None:
    ap = argparse.ArgumentParser(
        description="windowed, resumable scenario-grid sweeps")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume the sweep recorded under DIR (grid "
                         "flags come from its manifest)")
    ap.add_argument("--algo", default="decbyzpg",
                    help="decbyzpg | byzpg")
    ap.add_argument("--env", default="cartpole",
                    help="env spec, e.g. cartpole(horizon=100)")
    ap.add_argument("--T", type=int, default=50)
    ap.add_argument("--seeds", type=int, default=3,
                    help="seed batch size (seeds 0..N-1)")
    ap.add_argument("--windows", type=int, default=1,
                    help="window chunks T is split into (resume "
                         "granularity)")
    ap.add_argument("--axis", action="append", default=[],
                    metavar="NAME=V1,V2,...",
                    help="sweep axis over config-field values; repeat "
                         "per axis")
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    metavar="NAME=VALUE",
                    help="pin a base config field; repeat per field")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="sweep directory (manifest + window "
                         "checkpoints + summary.json); omit for an "
                         "in-memory run")
    ap.add_argument("--stop-after", type=int, default=None,
                    metavar="N", help="execute at most N windows then "
                    "exit (crash simulation / cooperative preemption)")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "local", "span", "shard"))
    ap.add_argument("--processes", type=int, default=1,
                    help="number of cooperating processes")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--coordinator", default="localhost:7733",
                    metavar="HOST:PORT")
    ap.add_argument("--telemetry-out", default=None, metavar="DIR",
                    help="stream sweep.window / sweep.partial records "
                         "to DIR/metrics.jsonl")
    args = ap.parse_args()

    if args.processes > 1:
        # must run before any other jax use: picks the CPU collective
        # transport and registers this process with the coordinator
        from repro.distributed.sharding import init_distributed
        init_distributed(args.coordinator, args.processes,
                         args.process_id)

    if args.resume is not None:
        runner = SweepRunner.resume(args.resume, mode=args.mode)
    else:
        axes = {}
        for item in args.axis:
            name, values = _parse_assign(item, "--axis")
            axes[name] = tuple(_parse_value(v)
                               for v in values.split(","))
        base = dict(_parse_assign(item, "--set") for item in args.sets)
        base = {k: _parse_value(v) for k, v in base.items()}
        runner = SweepRunner(algo=args.algo, env=args.env, T=args.T,
                             seeds=args.seeds, axes=axes,
                             windows=args.windows, out_dir=args.out,
                             mode=args.mode, **base)

    if args.telemetry_out:
        os.makedirs(args.telemetry_out, exist_ok=True)
        tele = obs.telemetry(obs.JsonlSink(
            os.path.join(args.telemetry_out, "metrics.jsonl")),
            obs.StdoutProgressSink())
    else:
        tele = contextlib.nullcontext()

    with tele:
        result = runner.run(max_windows=args.stop_after)

    if result is None:
        out = runner.out_dir or "(no --out)"
        print(f"sweep paused after --stop-after {args.stop_after} "
              f"window(s); resume with: python -m repro.launch.sweep "
              f"--resume {out}")
        return
    for name, entry in result.summary().items():
        print(f"{name}: final_return={entry['final_return_mean']:.3f} "
              f"+/- {entry['final_return_ci95']:.3f}")
    if runner.out_dir is not None:
        print(f"summary written to "
              f"{os.path.join(runner.out_dir, 'summary.json')}")


if __name__ == "__main__":
    main()
