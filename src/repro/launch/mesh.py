"""Production mesh construction (DESIGN.md §6).

A FUNCTION (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) ("data", "model") = 256 chips of TPU v5e.
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the "pod"
axis crosses the DCN boundary — exactly where the federation sits for the
large architectures (fed_axis="pod").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod=False):
    """Small mesh for CPU tests (requires enough fake devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link
