import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).

"""Multi-pod dry-run (deliverable (e)): ``lower().compile()`` every
(architecture × input shape) program on the production meshes and emit the
roofline inputs (memory_analysis, cost_analysis, collective wire bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed.fed_trainer import FedConfig, make_fed_step
from repro.distributed.serving import make_serve_fns, serve_cache_len
from repro.distributed.sharding import n_agents
from repro.launch.analysis import (collective_wire_bytes, model_flops,
                                   roofline_terms)
from repro.launch.mesh import make_production_mesh


def build_lowered(arch: str, shape_name: str, mesh, fed: FedConfig,
                  dtype=jnp.bfloat16, overrides=None):
    """Lower the program for one (arch, shape) on the given mesh.
    overrides: dict of ModelConfig field replacements (perf A/B toggles)."""
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if shape.mode == "train":
        K = n_agents(cfg, mesh)
        per_agent = max(shape.global_batch // K, 1)
        step, state_shape, batch, (state_sh, batch_sh, _) = make_fed_step(
            cfg, fed, mesh, large=True, dtype=dtype,
            per_agent_batch=per_agent, seq_len=shape.seq_len,
            key=key_struct)
        mask = jax.ShapeDtypeStruct((K,), jnp.bool_)
        return step.lower(state_shape, batch, mask, key_struct), cfg, shape

    B = shape.global_batch
    fns = make_serve_fns(cfg, mesh, B, shape.seq_len, dtype=dtype,
                         key=key_struct)
    params_shape = fns.params_shape
    if shape.mode == "prefill":
        S_text = shape.seq_len - cfg.n_prefix_embeds
        toks = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        if cfg.frontend != "none":
            pe = jax.ShapeDtypeStruct((B, cfg.n_prefix_embeds, cfg.d_model),
                                      dtype)
            return fns.prefill.lower(params_shape, toks, pe), cfg, shape
        return fns.prefill.lower(params_shape, toks), cfg, shape

    # decode: ONE new token against a cache of seq_len (ring for long ctx)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return fns.decode.lower(params_shape, tok, fns.cache_shape), cfg, shape


def run_one(arch: str, shape_name: str, multi_pod: bool,
            fed: FedConfig, overrides=None) -> dict:
    rec = {"arch": arch, "shape": shape_name, "overrides": overrides or {},
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.size
        with jax.set_mesh(mesh):
            lowered, cfg, shape = build_lowered(arch, shape_name, mesh, fed,
                                                overrides=overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0 - t_lower, 1)
        rec["lower_s"] = round(t_lower, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
                3),
        }
        cost = compiled.cost_analysis()
        from repro.models.model import n_block_stacks
        loop_scale = n_block_stacks(cfg)
        wire = collective_wire_bytes(compiled.as_text(),
                                     loop_scale=loop_scale)
        mf = model_flops(cfg, shape)
        terms = roofline_terms(cost, wire, n_chips,
                               model_flops_global=mf,
                               loop_scale=loop_scale)
        terms["model_flops_global"] = mf
        hlo_global = terms["flops_per_device"] * loop_scale * n_chips
        terms["useful_ratio"] = round(mf / hlo_global, 4) if hlo_global else 0
        rec["roofline"] = {k: (round(v, 6) if isinstance(v, float) else v)
                           for k, v in terms.items()}
        rec["collectives"] = {k: (int(v) if not isinstance(v, dict) else v)
                              for k, v in wire.items()}
        rec["n_agents"] = n_agents(get_config(arch), mesh)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--aggregator", default="rfa")
    ap.add_argument("--kappa", type=int, default=4)
    ap.add_argument("--mix-dtype", default=None)
    ap.add_argument("--mix-block", type=int, default=0)
    ap.add_argument("--override", default=None,
                    help="cfg overrides, e.g. fused_rmsnorm=1,mla_absorb=1,"
                         "recurrent_chunk=128")
    args = ap.parse_args()
    overrides = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=")
            overrides[k] = int(v) if v.lstrip("-").isdigit() else v
        overrides = {k: (bool(v) if k in ("fused_rmsnorm", "mla_absorb",
                                          "fsdp_layers") else v)
                     for k, v in overrides.items()}

    fed = FedConfig(aggregator=args.aggregator, kappa=args.kappa,
                    mix_dtype=args.mix_dtype, mix_block=args.mix_block)
    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    results = []
    for a, s in pairs:
        rec = run_one(a, s, args.multi_pod, fed, overrides=overrides)
        status = "OK " if rec["ok"] else "FAIL"
        extra = ""
        if rec["ok"]:
            r = rec["roofline"]
            extra = (f"bottleneck={r['bottleneck']} "
                     f"mem/dev={rec['memory']['peak_per_device_gb']}GB "
                     f"compile={rec['compile_s']}s")
        else:
            extra = rec["error"][:160]
        print(f"[{status}] {a:22s} {s:12s} {rec['mesh']:8s} {extra}",
              flush=True)
        results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(r["ok"] for r in results)
    print(f"{n_ok}/{len(results)} lowered+compiled")


if __name__ == "__main__":
    main()
