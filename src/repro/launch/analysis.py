"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute term    = per_device_HLO_FLOPs / peak_FLOP/s        [s]
  memory term     = per_device_HLO_bytes / HBM_bw             [s]
  collective term = per_device_wire_bytes / ICI_bw            [s]

``cost_analysis()`` on a partitioned module reports PER-DEVICE flops/bytes
(verified empirically); wire bytes come from parsing the optimized HLO's
collective ops: per-device ring-schedule bytes moved, derived from each
collective's output shape and replica-group size:

  all-gather          (g-1)/g * out
  all-reduce          2 (g-1)/g * out
  reduce-scatter      (g-1) * out          (input = g * out)
  all-to-all          (g-1)/g * out
  collective-permute  out
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\(?[\w\[\],{}\s/*]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_RE2 = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_wire_bytes(hlo_text: str,
                          loop_scale: int = 1) -> Dict[str, float]:
    """Per-device wire bytes by collective type from optimized HLO text.

    HLO cost analysis visits while-loop (lax.scan) bodies ONCE; collectives
    that live inside a non-entry computation (the layer scan's body — the
    per-layer tensor-parallel all-reduces) are therefore scaled by
    ``loop_scale`` (= the layer-stack trip count). The agreement loop is
    unrolled at trace time (see gda_agree) so its collectives are exact.
    """
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    in_entry = True
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
        elif line.startswith("%") and line.rstrip().endswith("{"):
            in_entry = False               # non-entry computation body
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                       # counted at -start
        scale = 1 if in_entry else loop_scale
        shape_str, op = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = 1
        gm = _GROUP_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm2 = _GROUP_RE2.search(line)
            if gm2:
                g = len(gm2.group(1).split(","))
        if g <= 1:
            continue
        if op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = size * (g - 1)
        elif op == "all-to-all":
            wire = size * (g - 1) / g
        else:                              # collective-permute
            wire = size
        out[op] += wire * scale
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def roofline_terms(cost: dict, wire: Dict[str, float], n_chips: int,
                   model_flops_global: float = 0.0,
                   loop_scale: int = 1) -> dict:
    """The three §Roofline terms (seconds) + dominant bottleneck.

    HLO flops/bytes from ``cost_analysis`` count while bodies once, so we
    scale them by ``loop_scale`` (the layer trip count) as an upper proxy
    and ALSO report the analytic MODEL_FLOPS compute term; the compute term
    used for the bottleneck is the analytic one (standard MFU practice),
    with the HLO-derived one kept as a diagnostic.
    """
    # HLO flops count while bodies once -> the layer loop is undercounted;
    # the analytic MODEL_FLOPS term is authoritative for compute. HLO bytes
    # already include full stacked parameter/activation arrays (read once
    # per step), so the memory term stays unscaled.
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    t_compute_hlo = flops * loop_scale / PEAK_FLOPS_BF16
    t_compute = (model_flops_global / n_chips) / PEAK_FLOPS_BF16 \
        if model_flops_global else t_compute_hlo
    t_memory = bytes_acc / HBM_BW
    t_coll = float(wire.get("total", 0.0)) / ICI_BW_PER_LINK
    terms = {"compute_s": t_compute, "compute_hlo_s": t_compute_hlo,
             "memory_s": t_memory, "collective_s": t_coll}
    terms["bottleneck"] = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    terms["flops_per_device"] = flops
    terms["bytes_per_device"] = bytes_acc
    terms["wire_bytes_per_device"] = float(wire.get("total", 0.0))
    return terms


def model_flops(cfg, shape, n_tokens=None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode counts the
    single generated token per sequence."""
    if n_tokens is None:
        if shape.mode == "decode":
            n_tokens = shape.global_batch           # one token per sequence
        else:
            n_tokens = shape.global_batch * shape.seq_len
    n = cfg.n_active_params()
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n * n_tokens
