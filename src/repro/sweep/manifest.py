"""Sweep manifest (DESIGN.md §12): the on-disk record that makes a
windowed grid run resumable.

Layout of a sweep directory::

    manifest.json            static sweep description, written once:
                             version, meta (algo/env/T/seeds/axes/base —
                             everything needed to reconstruct the grid),
                             the window slices, and one entry per lane
                             group (gid, lane count, rows, pad rows,
                             static-signature string, scenario names)
    groupNNN.state.json      per-group progress: {"windows_done": w,
                             "t_done": t} — committed *after* the carry
                             and chunk for window w-1 land on disk
    groupNNN.carry.npz       the group's carry stack after its last
                             committed window (repro.checkpoint format)
    groupNNN.winMMM.npz      window M's history chunk (flat dict of
                             arrays, time axis 1)
    summary.json             final ``ExperimentResult.to_json`` document,
                             written when every group completes

All JSON/npz writes are atomic (temp sibling + ``os.replace``), and the
state file is committed last, so a crash at any point leaves either a
fully committed window or a cleanly re-runnable one — never a torn
resume point.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

MANIFEST = "manifest.json"
SUMMARY = "summary.json"
VERSION = 1


class SweepMismatch(ValueError):
    """A resume directory's manifest disagrees with the requested sweep;
    the message names every differing field."""


def write_json(path: str, doc: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + f".tmp-{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def read_json(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


@dataclasses.dataclass(frozen=True)
class GroupPaths:
    """File names for one lane group's artifacts under a sweep dir."""
    out_dir: str
    gid: int

    @property
    def stem(self) -> str:
        return os.path.join(self.out_dir, f"group{self.gid:03d}")

    @property
    def state(self) -> str:
        return self.stem + ".state.json"

    @property
    def carry(self) -> str:
        return self.stem + ".carry.npz"

    def window(self, w: int) -> str:
        return self.stem + f".win{w:03d}.npz"


def windows_done(paths: GroupPaths) -> int:
    """Committed window count for a group (0 when it never started)."""
    if not os.path.exists(paths.state):
        return 0
    return int(read_json(paths.state).get("windows_done", 0))


def commit_window(paths: GroupPaths, windows_done: int, t_done: int) \
        -> None:
    """Mark ``windows_done`` windows committed — call only after the
    matching carry + chunk files are on disk (write ordering is the
    crash-safety contract)."""
    write_json(paths.state, {"windows_done": int(windows_done),
                             "t_done": int(t_done)})


def build_manifest(meta: dict, slices, group_entries) -> dict:
    """The static sweep description (see module docstring)."""
    return {"version": VERSION, "meta": meta,
            "window_slices": [list(s) for s in slices],
            "groups": list(group_entries)}


def check_manifest(on_disk: dict, wanted: dict) -> None:
    """Raise :class:`SweepMismatch` naming every field where the resumed
    directory's manifest disagrees with the sweep being requested."""
    problems = []
    if on_disk.get("version") != wanted["version"]:
        problems.append(f"version: {on_disk.get('version')} != "
                        f"{wanted['version']}")
    old_meta, new_meta = on_disk.get("meta", {}), wanted["meta"]
    for k in sorted(set(old_meta) | set(new_meta)):
        if old_meta.get(k) != new_meta.get(k):
            problems.append(f"meta.{k}: {old_meta.get(k)!r} != "
                            f"{new_meta.get(k)!r}")
    if on_disk.get("window_slices") != wanted["window_slices"]:
        problems.append(
            f"window_slices: {on_disk.get('window_slices')} != "
            f"{wanted['window_slices']}")
    old_g, new_g = on_disk.get("groups", []), wanted["groups"]
    if len(old_g) != len(new_g):
        problems.append(f"group count: {len(old_g)} != {len(new_g)}")
    else:
        for og, ng in zip(old_g, new_g):
            for k in ("gid", "signature", "lanes", "rows", "n_pad"):
                if og.get(k) != ng.get(k):
                    problems.append(
                        f"group {ng.get('gid')}.{k}: {og.get(k)!r} != "
                        f"{ng.get(k)!r}")
    if problems:
        raise SweepMismatch(
            "resume directory manifest does not describe this sweep "
            f"({len(problems)} field(s)): " + "; ".join(problems))


def load_or_init(out_dir: str, wanted: dict, write: bool = True) \
        -> Optional[dict]:
    """Validate an existing ``manifest.json`` against ``wanted`` (raising
    :class:`SweepMismatch` on disagreement) or write ``wanted`` as the new
    manifest (when ``write``; multi-process readers pass False and wait
    for the writer).  Returns the on-disk manifest, or None when absent
    and not written."""
    path = os.path.join(out_dir, MANIFEST)
    if os.path.exists(path):
        doc = read_json(path)
        check_manifest(doc, wanted)
        return doc
    if write:
        write_json(path, wanted)
        return wanted
    return None
