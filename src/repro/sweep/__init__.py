"""repro.sweep — windowed, resumable, multi-host sweep service
(DESIGN.md §12).

:class:`SweepRunner` drives :class:`repro.Experiment`-shaped scenario
grids as long-running jobs: T chunked into windows through the engine's
explicit-carry window programs (bit-identical to the one-shot scan),
per-window checkpoints + a sweep manifest under ``out_dir`` for
kill-and-resume, process-spanning lane meshes (or per-process group
sharding) when launched under ``jax.distributed``, and partial summaries
streamed through ``repro.obs`` sinks.  CLI:
``python -m repro.launch.sweep``.
"""
from repro.sweep.manifest import (MANIFEST, SUMMARY, GroupPaths,
                                  SweepMismatch, build_manifest,
                                  check_manifest, commit_window,
                                  read_json, windows_done, write_json)
from repro.sweep.runner import SweepError, SweepRunner

__all__ = [
    "SweepRunner", "SweepError", "SweepMismatch",
    "MANIFEST", "SUMMARY", "GroupPaths",
    "build_manifest", "check_manifest", "commit_window", "windows_done",
    "read_json", "write_json",
]
