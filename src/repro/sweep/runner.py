"""SweepRunner — windowed, resumable, multi-host grid driver
(DESIGN.md §12).

Where :class:`repro.Experiment` runs a scenario grid as one one-shot
``run_grid`` call, the sweep service drives the *same* grid as a
long-running job built from the engine's windowed programs:

* T is chunked into W windows (:func:`repro.core.engine.window_slices`)
  and each lane group advances one window at a time through
  :func:`repro.core.engine.lane_window_loop`, whose explicit carry makes
  the chain bit-identical to the uninterrupted scan;
* after every window the carry, the history chunk, and the group's
  progress record land in the sweep directory (atomic writes, progress
  committed last), so a preempted sweep resumes from its manifest:
  completed lane groups are reloaded without compiling or dispatching
  anything, partial ones restart mid-T from their carry;
* with multiple processes (``jax.process_count() > 1`` after
  :func:`repro.distributed.sharding.init_distributed`) the flattened
  lane×seed batch spans all processes' devices on a ``spanning`` lane
  mesh — or, in ``mode="shard"``, whole lane groups are partitioned
  across processes by greedy longest-processing-time assignment and
  merged through the shared sweep directory;
* partial summaries stream through ``repro.obs`` sinks as windows and
  lane groups finish (``sweep.window`` / ``sweep.partial`` records).

CLI: ``python -m repro.launch.sweep`` (``--windows``, ``--resume DIR``,
``--processes`` — see README "Resumable sweeps").
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Callable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import restore, save
from repro.core import engine
from repro.core.registry import Spec
from repro.distributed.sharding import (global_rows, host_assignment,
                                        lane_mesh, padded_rows,
                                        spans_processes, use_lane_mesh)
from repro.rl.envs import make_env
from repro.sweep import manifest as mf

SweepMismatch = mf.SweepMismatch


class SweepError(RuntimeError):
    """Unrecoverable sweep-service condition (bad mode, merge timeout,
    non-persistable configuration)."""


def _jsonable(v):
    if isinstance(v, Spec):
        return v.canonical()
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    raise SweepError(
        f"cannot persist {v!r} in a sweep manifest; use spec strings "
        f"and plain scalars for axes/base fields of a resumable sweep")


def _from_json(v):
    """Undo the JSON round-trip of :func:`_jsonable`: sequences come back
    as lists but configs need the hashable tuple form (hidden=(8,))."""
    if isinstance(v, list):
        return tuple(_from_json(x) for x in v)
    return v


class SweepRunner:
    """Drive an Experiment-shaped grid as a windowed, resumable job.

    Constructor arguments mirror :class:`repro.Experiment` (``algo``,
    ``env``, ``T``, ``seeds``, ``axes``, ``override``, plus base config
    fields), with the service knobs on top:

    ``windows``
        number of window chunks T is split into (1 = one-shot-sized
        windows, still through the windowed programs).
    ``out_dir``
        sweep directory for the manifest + per-group checkpoints; None
        runs fully in memory (not resumable).
    ``mode``
        ``"auto"`` (spanning mesh when multiple processes are present,
        plain local execution otherwise), ``"span"``, ``"shard"`` (one
        lane group per process, greedy LPT-balanced, merged through
        ``out_dir``), or ``"local"``.

    ``run(max_windows=N)`` executes at most N windows and returns None
    if the sweep is unfinished (the crash-simulation hook CI's resume
    smoke uses); a later ``run()`` — or ``SweepRunner.resume(out_dir)``
    in a fresh process — picks up from the manifest.  The completed
    sweep returns an :class:`repro.ExperimentResult` bit-identical to
    the one-shot ``run_grid`` over the same grid.
    """

    def __init__(self, algo="decbyzpg", env="cartpole", T: int = 50,
                 seeds=(0, 1, 2), axes: Optional[Mapping] = None,
                 override: Optional[Callable] = None, windows: int = 1,
                 out_dir: Optional[str] = None, mode: str = "auto",
                 poll_s: float = 0.2, timeout_s: float = 600.0, **base):
        self.algo = Spec.of(algo)
        self.env_spec = env
        self.T = int(T)
        self.seeds = tuple(range(seeds)) if isinstance(seeds, int) \
            else tuple(seeds)
        self.axes = {k: engine._as_axis(tuple(v) if isinstance(v, list)
                                        else v)
                     for k, v in dict(axes or {}).items()}
        self.override = override
        self.windows = int(windows)
        self.out_dir = out_dir
        if mode not in ("auto", "local", "span", "shard"):
            raise SweepError(f"unknown sweep mode {mode!r}")
        self.mode = mode
        self.poll_s = float(poll_s)
        self.timeout_s = float(timeout_s)
        self.base = base

    @classmethod
    def resume(cls, out_dir: str, override: Optional[Callable] = None,
               mode: str = "auto", **kw) -> "SweepRunner":
        """Reconstruct a runner from ``out_dir``'s manifest.  A sweep
        recorded with an ``override`` hook cannot round-trip the hook
        itself — pass the same function again or this raises."""
        doc = mf.read_json(os.path.join(out_dir, mf.MANIFEST))
        m = doc["meta"]
        if m.get("override") and override is None:
            raise SweepError(
                f"sweep was recorded with override hook "
                f"{m['override']!r}; pass override= to resume()")
        base = {k: _from_json(v) for k, v in m["base"].items()}
        return cls(algo=m["algo"], env=m["env"], T=m["T"],
                   seeds=tuple(m["seeds"]),
                   axes={k: tuple(_from_json(x) for x in v)
                         for k, v in m["axes"]},
                   override=override, windows=m["windows"],
                   out_dir=out_dir, mode=mode, **{**base, **kw})

    # -- sweep description ---------------------------------------------------

    def _meta(self) -> dict:
        env = self.env_spec
        return {"algo": self.algo.canonical(),
                "env": (Spec.of(env).canonical()
                        if isinstance(env, (str, Spec)) else env.name),
                "T": self.T, "seeds": list(self.seeds),
                "windows": self.windows,
                # list of [name, values] pairs, NOT a mapping: axis order
                # defines the scenario-key tuples and must survive the
                # sort_keys JSON round-trip
                "axes": [[k, [_jsonable(v) for v in vals]]
                         for k, vals in self.axes.items()],
                "base": {k: _jsonable(v) for k, v in self.base.items()},
                "override": (getattr(self.override, "__qualname__",
                                     repr(self.override))
                             if self.override is not None else None)}

    # -- execution -----------------------------------------------------------

    def run(self, max_windows: Optional[int] = None) \
            -> Optional[engine.ExperimentResult]:
        """Advance the sweep; returns the completed
        :class:`repro.ExperimentResult`, or None when ``max_windows``
        ran out first (progress is committed — call again to continue)."""
        env = make_env(self.env_spec)
        grid = engine.ScenarioGrid(seeds=self.seeds, axes=self.axes)
        _, scenarios = engine.grid_scenarios(
            grid, algo=self.algo, override=self.override,
            base=dict(self.base))
        groups = list(engine.lane_groups(scenarios, algo=self.algo)
                      .items())
        slices = engine.window_slices(self.T, self.windows)
        n_proc, pid = jax.process_count(), jax.process_index()
        mode = self.mode
        if mode == "auto":
            mode = "span" if n_proc > 1 else "local"
        if mode == "shard" and n_proc > 1 and self.out_dir is None:
            raise SweepError(
                "mode='shard' needs a shared out_dir to merge groups")
        ctx = use_lane_mesh(lane_mesh(spanning=True)) \
            if mode == "span" and n_proc > 1 else contextlib.nullcontext()
        with ctx:
            return self._run(env, scenarios, groups, slices, mode,
                             n_proc, pid, max_windows)

    def _run(self, env, scenarios, groups, slices, mode, n_proc, pid,
             max_windows):
        mesh = lane_mesh()
        S = len(self.seeds)
        entries = []
        for gi, ((static_cfg, names), members) in enumerate(groups):
            rows = len(members) * S
            entries.append({
                "gid": gi, "signature": f"{static_cfg!r}|{names!r}",
                "lanes": len(members), "rows": rows,
                "n_pad": padded_rows(mesh, rows),
                "scenarios": [engine.ExperimentResult.scenario_name(s)
                              for s, _, _ in members]})
        persist = self.out_dir is not None
        # manifest writer: rank 0 creates it, everyone validates theirs
        # against it (a mismatched resume dir fails before any compute)
        if persist:
            wanted = mf.build_manifest(self._meta(), slices, entries)
            doc = mf.load_or_init(self.out_dir, wanted, write=(pid == 0))
            deadline = time.time() + self.timeout_s
            while doc is None:      # non-zero ranks wait for the writer
                if time.time() > deadline:
                    raise SweepError("timed out waiting for manifest")
                time.sleep(self.poll_s)
                doc = mf.load_or_init(self.out_dir, wanted,
                                      write=(pid == 0))
        owners = host_assignment(
            [e["rows"] * self.T for e in entries], n_proc) \
            if mode == "shard" else None
        budget = [max_windows] if max_windows is not None else None
        results: dict = {}
        pending = []
        for gi, ((static_cfg, names), members) in enumerate(groups):
            if owners is not None and owners[gi] != pid:
                pending.append((gi, static_cfg, names, members))
                continue
            writer = persist and (pid == 0 if mode == "span" else True)
            gp = mf.GroupPaths(self.out_dir, gi) if persist else None
            hist = self._run_group(env, static_cfg, names, members, gi,
                                   gp, slices, entries[gi]["n_pad"],
                                   budget, writer, mesh)
            if hist is None:        # max_windows exhausted mid-sweep
                return None
            self._summarize_group(hist, members, results, gi,
                                  len(groups))
        # shard mode: groups owned by other processes arrive through the
        # shared sweep dir once their state says every window committed
        deadline = time.time() + self.timeout_s
        for gi, static_cfg, names, members in pending:
            gp = mf.GroupPaths(self.out_dir, gi)
            while mf.windows_done(gp) < len(slices):
                if time.time() > deadline:
                    raise SweepError(
                        f"timed out waiting for group {gi} (owner "
                        f"process {owners[gi]}) to finish")
                time.sleep(self.poll_s)
            hist = self._load_group(env, static_cfg, members, gp,
                                    len(slices))
            self._summarize_group(hist, members, results, gi,
                                  len(groups))
        ordered = {scn: results[scn] for scn, _ in scenarios}
        meta = self._meta()
        result = engine.ExperimentResult(meta, self.axes, ordered)
        if persist and pid == 0:
            result.to_json(os.path.join(self.out_dir, mf.SUMMARY))
        return result

    def _run_group(self, env, static_cfg, names, members, gi, gp,
                   slices, n_pad, budget, writer, mesh):
        W = len(slices)
        wdone = mf.windows_done(gp) if gp is not None else 0
        if wdone >= W:
            # fully committed: reload artifacts — no compile, no dispatch
            return self._load_group(env, static_cfg, members, gp, W)
        span = spans_processes(mesh)
        seeds = jnp.asarray(self.seeds, jnp.int32)
        vals_flat, seeds_flat = engine.lane_operands(members, seeds,
                                                     n_pad)
        if span:
            # every process holds the same host operands; assemble the
            # global arrays each process's devices need shards of
            vals_flat = global_rows(mesh, np.asarray(vals_flat))
            seeds_flat = global_rows(mesh, np.asarray(seeds_flat))
        if wdone == 0:
            init = engine.lane_init_loop(env, static_cfg, n_pad,
                                         self.algo)
            carry = init(seeds_flat)
        else:
            carry = restore(
                engine.lane_carry_struct(env, static_cfg, n_pad,
                                         self.algo), gp.carry)
        chunks = [self._load_chunk(gp.window(w)) for w in range(wdone)]
        for w in range(wdone, W):
            if budget is not None and budget[0] <= 0:
                return None
            start, stop = slices[w]
            win = engine.lane_window_loop(env, static_cfg, self.T,
                                          names, stop - start, n_pad,
                                          self.algo)
            # spanning meshes hand carries back fully replicated (so any
            # host can checkpoint them); re-shard by row before the next
            # window — jit refuses to silently reshard committed global
            # arrays whose layout disagrees with in_shardings
            carry_dev = jax.tree.map(
                lambda x: global_rows(mesh, np.asarray(x)), carry) \
                if span else carry
            carry, ch = jax.block_until_ready(
                win(carry_dev, vals_flat, seeds_flat,
                    np.arange(start, stop)))
            chunks.append(ch)
            if budget is not None:
                budget[0] -= 1
            if writer and gp is not None:
                # carry + chunk first, progress record last: a crash
                # between the writes re-runs window w, never skips it
                save(carry, gp.carry)
                save(dict(ch), gp.window(w))
                mf.commit_window(gp, w + 1, stop)
            if obs.enabled():
                obs.record("sweep.window", group=gi, window=w,
                           t_done=stop, T=self.T)
                obs.progress(f"sweep group {gi}: window {w + 1}/{W} "
                             f"(t={stop}/{self.T})", group=gi, window=w)
        return engine.assemble_hist(carry, chunks, self.algo)

    def _load_group(self, env, static_cfg, members, gp, W):
        # carry template from eval_shape: restore validates names/shapes
        # without building or dispatching any program
        rows = len(members) * len(self.seeds)
        n_pad = padded_rows(lane_mesh(), rows)
        carry = restore(
            engine.lane_carry_struct(env, static_cfg, n_pad, self.algo),
            gp.carry)
        chunks = [self._load_chunk(gp.window(w)) for w in range(W)]
        return engine.assemble_hist(carry, chunks, self.algo)

    @staticmethod
    def _load_chunk(path: str) -> dict:
        data = np.load(path)
        return {k: data[k] for k in data.files}

    def _summarize_group(self, hist, members, results, gi, n_groups):
        S = len(self.seeds)
        for i, (scn, cfg, _) in enumerate(members):
            # pad rows (if any) sit past i == len(members) - 1: never read
            lane = {k: v[i * S:(i + 1) * S] for k, v in hist.items()}
            results[scn] = engine.summarize(lane, cfg)
        if obs.enabled():
            for scn, _, _ in members:
                r = results[scn]
                obs.record(
                    "sweep.partial",
                    scenario=engine.ExperimentResult.scenario_name(scn),
                    final_return_mean=r["final_return_mean"],
                    final_return_ci95=r["final_return_ci95"])
            obs.progress(f"sweep group {gi + 1}/{n_groups} complete",
                         group=gi, scenarios=len(members))
