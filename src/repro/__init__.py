"""repro — Byzantine fault-tolerant decentralized federated policy
gradient (arXiv 2401.03489 reproduction) on jax.

This module is the deliberate public surface.  Everything here resolves
lazily (PEP 562): ``import repro`` costs one dict, and each name pulls
in only its own submodule on first touch — so ``repro.obs`` never drags
the training stack in, and leaf modules keep importing their own
internals without cycles.

Stable entry points:

* ``repro.Experiment`` / ``repro.ScenarioGrid`` / ``repro.run_grid`` —
  configure and run the paper's experiments
* ``repro.register`` / ``repro.resolve`` / ``repro.REGISTRY`` — the
  spec-string registry (aggregators, attacks, envs, policies, ...)
* ``repro.save`` / ``repro.restore`` — checkpoint pytrees
* ``repro.SweepRunner`` — windowed, resumable, multi-host sweeps
* ``repro.serve`` — continuous-batching decode of the aggregated policy
* ``repro.obs`` / ``repro.serving`` / ``repro.core`` — the subsystem
  namespaces themselves

Anything not exported here is internal: examples and downstream code
should not deep-import paths like ``repro.core.engine`` for names this
surface already provides (``repro.analysis`` lints exactly that).
"""
import importlib

#: name -> defining submodule (attribute re-exports)
_EXPORTS = {
    "Experiment": "repro.core.engine",
    "ExperimentResult": "repro.core.engine",
    "Scenario": "repro.core.engine",
    "ScenarioGrid": "repro.core.engine",
    "run_grid": "repro.core.engine",
    "REGISTRY": "repro.core.registry",
    "Spec": "repro.core.registry",
    "SpecError": "repro.core.registry",
    "register": "repro.core.registry",
    "resolve": "repro.core.registry",
    "get_config": "repro.configs.base",
    "reduced": "repro.configs.base",
    "make_env": "repro.rl.envs",
    "save": "repro.checkpoint",
    "restore": "repro.checkpoint",
    "serve": "repro.serving",
    "SweepRunner": "repro.sweep",
}

#: subsystem namespaces exposed as attributes (lazy submodule imports)
_MODULES = ("analysis", "checkpoint", "configs", "core", "data",
            "distributed", "kernels", "launch", "models", "obs", "optim",
            "rl", "serving", "sweep", "topology")

__all__ = sorted(_EXPORTS) + sorted(_MODULES)


def __getattr__(name):
    module = _EXPORTS.get(name)
    if module is not None:
        return getattr(importlib.import_module(module), name)
    if name in _MODULES:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return __all__
