"""Communication-topology subsystem: gossip graphs for decentralized
agreement (DESIGN.md §5)."""
from repro.topology.graphs import (Topology, make_topology,
                                   resolve_topology)

__all__ = ["Topology", "make_topology", "resolve_topology"]
