"""Communication topologies for decentralized agreement (DESIGN.md §5).

A topology is a static directed graph over the K agents: ``adjacency[r, s]``
means receiver ``r`` hears sender ``s``. Every agent always hears itself
(the diagonal is forced True), matching the paper's convention that an
agent's own vector is part of the multiset it selects over.

Topologies are *static*: generators produce trace-time numpy adjacency
masks, so a ``topology`` spec can sit in a frozen config dataclass, flow
through ``engine.static_key``, and select a compiled-loop cache entry the
same way an aggregator spec does. The runtime representation is the
padded neighbor-index table ``nbr_idx (K, deg_max)`` — receiver ``r``'s
sender indices in ascending order, padded with ``r`` itself — so the
agreement core gathers a fixed-shape ``(K, deg_max, d)`` received tensor
that vmaps and jits regardless of per-receiver degree. Padding with the
receiver's own index (rather than a sentinel + validity mask) keeps every
slot a real message: low-degree agents simply see extra copies of their
own value, a lazy-gossip self-weight that needs no masked selection rule.
On the complete graph ``nbr_idx[r] == arange(K)``, so the gather is the
identity and the masked core reproduces the historical all-to-all
broadcast exactly.

Diagnostics bound Byzantine feasibility: ``min_in_degree`` (excluding
self) upper-bounds vertex connectivity, the Fiedler value
``algebraic_connectivity`` of the symmetrized graph lower-bounds it
(Fiedler's inequality), and ``spectral_gap`` of the uniform gossip matrix
governs the honest-diameter contraction rate. The classic BFT condition
is connectivity > 2·n_byz; :meth:`Topology.tolerates` checks the
*necessary* version of it against ``min_in_degree``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core.registry import Spec, register, resolve


class Topology(NamedTuple):
    """A resolved communication graph plus its diagnostics.

    ``adjacency`` is the (K, K) bool mask (diagonal True); ``nbr_idx`` the
    padded (K, deg_max) int32 sender table the agreement core gathers
    with; degrees and spectra are trace-time floats/ints for reporting.
    """
    spec: Spec
    adjacency: np.ndarray            # (K, K) bool, adjacency[r, s]
    nbr_idx: np.ndarray              # (K, deg_max) int32, padded with self
    in_degree: np.ndarray            # (K,) int32, including self
    min_in_degree: int               # excluding self
    spectral_gap: float              # 1 - |lambda_2| of uniform gossip W
    algebraic_connectivity: float    # Fiedler value of symmetrized graph

    @property
    def K(self) -> int:
        return self.adjacency.shape[0]

    @property
    def deg_max(self) -> int:
        return self.nbr_idx.shape[1]

    @property
    def name(self) -> str:
        return self.spec.canonical()

    @property
    def density(self) -> float:
        """Off-diagonal edge fraction in [0, 1] (1 = complete)."""
        K = self.K
        if K <= 1:
            return 1.0
        off = int(self.adjacency.sum()) - K
        return off / (K * (K - 1))

    def is_complete(self) -> bool:
        return bool(self.adjacency.all())

    def tolerates(self, n_byz: int) -> bool:
        """Necessary BFT condition: every agent hears > 2·n_byz peers
        (vertex connectivity <= min degree, and connectivity > 2f is the
        classic requirement for agreement with f Byzantine nodes)."""
        return self.min_in_degree > 2 * n_byz


def make_topology(spec, adjacency: np.ndarray) -> Topology:
    """Wrap a raw adjacency mask with its padded gather table and
    diagnostics (all trace-time numpy; no jax involvement)."""
    adj = np.array(adjacency, dtype=bool)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    np.fill_diagonal(adj, True)
    K = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.int32)               # including self
    deg_max = int(deg.max())
    nbr = np.empty((K, deg_max), dtype=np.int32)
    for r in range(K):
        senders = np.flatnonzero(adj[r])
        nbr[r, :len(senders)] = senders
        nbr[r, len(senders):] = r                        # pad with self
    W = adj / deg[:, None]
    if K > 1:
        mags = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
        gap = float(1.0 - mags[1])
        und = (adj | adj.T).copy()
        np.fill_diagonal(und, False)
        lap = np.diag(und.sum(axis=1)) - und.astype(np.float64)
        fiedler = float(np.sort(np.linalg.eigvalsh(lap))[1])
    else:
        gap, fiedler = 1.0, 0.0
    return Topology(Spec.of(spec), adj, nbr, deg,
                    int((deg - 1).min()), gap, fiedler)


# ---------------------------------------------------------------------------
# Generators (registry namespace "topology") — each returns a (K, K) bool
# adjacency; ``resolve_topology`` wraps it into a Topology. Random graphs
# take an explicit ``seed`` kwarg (numpy, trace-time) so a spec string like
# "erdos_renyi(p=0.4, seed=1)" is fully deterministic and cache-stable.
# ---------------------------------------------------------------------------


@register("topology", "complete")
def _complete(K: int) -> np.ndarray:
    """All-to-all broadcast — the paper's Algorithm 3 setting."""
    return np.ones((K, K), dtype=bool)


def _ring_lattice(K: int, k: int) -> np.ndarray:
    adj = np.eye(K, dtype=bool)
    idx = np.arange(K)
    for off in range(1, k // 2 + 1):
        adj[idx, (idx + off) % K] = True
        adj[idx, (idx - off) % K] = True
    return adj


@register("topology", "ring")
def _ring(K: int, k: int = 2) -> np.ndarray:
    """Ring lattice: each agent hears its k nearest ring neighbors
    (k/2 on each side). ``k`` must be even; ``k >= K-1`` is complete."""
    if k < 2 or k % 2:
        raise ValueError(f"ring degree k must be even and >= 2, got {k}")
    if k >= K - 1:
        return _complete(K)
    return _ring_lattice(K, k)


@register("topology", "torus")
def _torus(K: int, rows: Optional[int] = None) -> np.ndarray:
    """2D torus grid with wraparound 4-neighborhoods. ``rows`` defaults to
    the largest divisor of K that is <= sqrt(K) (1 for prime K, which
    degenerates to a ring)."""
    if rows is None:
        rows = max(r for r in range(1, int(np.sqrt(K)) + 1) if K % r == 0)
    if K % rows:
        raise ValueError(f"torus rows={rows} does not divide K={K}")
    cols = K // rows
    adj = np.eye(K, dtype=bool)
    r, c = np.divmod(np.arange(K), cols)
    for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        adj[np.arange(K), ((r + dr) % rows) * cols + (c + dc) % cols] = True
    return adj


@register("topology", "erdos_renyi")
def _erdos_renyi(K: int, p: float = 0.5, seed: int = 0) -> np.ndarray:
    """Undirected Erdős–Rényi G(K, p): each unordered pair is an edge with
    probability ``p``. May be disconnected — check the diagnostics."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"erdos_renyi edge probability p={p} not in [0,1]")
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.random((K, K)) < p, k=1)
    return upper | upper.T | np.eye(K, dtype=bool)


@register("topology", "small_world")
def _small_world(K: int, k: int = 4, beta: float = 0.3,
                 seed: int = 0) -> np.ndarray:
    """Watts–Strogatz: ring lattice of degree ``k`` with each rightward
    edge rewired to a uniform random target with probability ``beta``
    (undirected; self-loops and duplicate edges are skipped)."""
    if k < 2 or k % 2:
        raise ValueError(f"small_world degree k must be even >= 2, got {k}")
    if k >= K - 1:
        return _complete(K)
    if not 0.0 <= beta <= 1.0:
        raise ValueError(f"small_world beta={beta} not in [0,1]")
    adj = _ring_lattice(K, k)
    np.fill_diagonal(adj, False)
    rng = np.random.default_rng(seed)
    for off in range(1, k // 2 + 1):
        for i in range(K):
            j = (i + off) % K
            if rng.random() < beta:
                target = int(rng.integers(K))
                if target == i or adj[i, target]:
                    continue                  # keep the original edge
                adj[i, j] = adj[j, i] = False
                adj[i, target] = adj[target, i] = True
    return adj | np.eye(K, dtype=bool)


@register("topology", "star")
def _star(K: int, center: int = 0) -> np.ndarray:
    """Hub-and-spoke: the center hears everyone and everyone hears the
    center — the FedPG-BR trusted-server communication pattern, expressed
    as a graph (and exactly as fragile: connectivity 1)."""
    if not 0 <= center < K:
        raise ValueError(f"star center={center} out of range for K={K}")
    adj = np.eye(K, dtype=bool)
    adj[center, :] = True
    adj[:, center] = True
    return adj


# ---------------------------------------------------------------------------
# Resolution + trace-time cache
# ---------------------------------------------------------------------------

_CACHE: dict = {}


def resolve_topology(topology, K: int) -> Topology:
    """Resolve a topology reference (None | str | Spec | Topology) against
    a federation of size K. ``None`` means the historical complete
    broadcast. Resolved topologies are cached per (spec, K) — generators
    run numpy eigendecompositions that shouldn't repeat per trace."""
    if isinstance(topology, Topology):
        if topology.K != K:
            raise ValueError(f"topology {topology.name!r} is over "
                             f"{topology.K} agents, config has K={K}")
        return topology
    spec = Spec.of(topology) if topology is not None else Spec("complete")
    cache_key = (spec, K)
    topo = _CACHE.get(cache_key)
    if topo is None:
        adj = resolve("topology", spec, K=K)
        topo = _CACHE[cache_key] = make_topology(spec, adj)
    return topo
