from repro.optim.optimizers import (adam, cosine_schedule, get_optimizer,
                                    sgd)
