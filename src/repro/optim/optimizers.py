"""Optimizers (pure-pytree, optax-free): SGD+momentum, Adam, schedules.

``update(grads, state, params)`` returns (new_params, new_state) with
gradient-ASCENT semantics (policy gradient maximizes J); pass
``maximize=False`` for descent (supervised losses).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.registry import Spec, register, resolve


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


class MomentumState(NamedTuple):
    m: object


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable      # (grads, state, params) -> (params, state)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         maximize: bool = True) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return AdamState(jnp.zeros((), jnp.int32), z,
                         jax.tree.map(jnp.zeros_like, params))

    def update(g, s, params):
        step = s.step + 1
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, s.m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, s.v, g)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        sign = 1.0 if maximize else -1.0
        upd = jax.tree.map(
            lambda mm, vv: sign * lr_fn(step) * (mm / bc1)
            / (jnp.sqrt(vv / bc2) + eps), m, v)
        params = jax.tree.map(jnp.add, params, upd)
        return params, AdamState(step, m, v)

    return Optimizer(init, update)


def sgd(lr, momentum: float = 0.0, maximize: bool = True) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return MomentumState(jax.tree.map(jnp.zeros_like, params))

    def update(g, s, params):
        m = jax.tree.map(lambda a, b: momentum * a + b, s.m, g)
        sign = 1.0 if maximize else -1.0
        params = jax.tree.map(lambda p, mm: p + sign * lr_fn(0) * mm,
                              params, m)
        return params, MomentumState(m)

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


register("optimizer", "adam")(adam)
register("optimizer", "sgd")(sgd)


def get_optimizer(name, lr, **kw) -> Optimizer:
    """Resolve an optimizer spec (``"adam"``, ``"sgd(momentum=0.9)"``, or a
    Spec) at learning rate ``lr``; extra ``kw`` (e.g. ``maximize=False``)
    merge into the spec's kwargs."""
    spec = Spec.of(name)
    if kw:
        spec = spec.with_kwargs(**kw)
    return resolve("optimizer", spec, lr=lr)
