"""Generic (supervised) PAGE estimator [17] — the probabilistic-switch
variance-reduced gradient used by ByzPG/DecByzPG. For stationary data
(the LLM path) the importance weight is identically 1 and PAGE takes its
original form; the RL drivers implement the importance-sampled variant.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PageState(NamedTuple):
    v: object             # running direction (pytree like params)
    prev_params: object


def init_page(params) -> PageState:
    return PageState(jax.tree.map(jnp.zeros_like, params), params)


def page_direction(grad_fn: Callable, params, state: PageState, batch,
                   use_large: bool) -> PageState:
    """grad_fn(params, batch) -> grad pytree.

    use_large=True: v = ĝ(θ_t) (fresh large-batch estimate).
    use_large=False: v = ĝ_B(θ_t) − ĝ_B(θ_{t-1}) + v_{t-1} (PAGE correction,
    both estimates on the SAME small batch).
    Returns the new state; the direction is ``state.v``.
    """
    g_new = grad_fn(params, batch)
    if use_large:
        v = g_new
    else:
        g_old = grad_fn(state.prev_params, batch)
        v = jax.tree.map(lambda a, b, c: a - b + c, g_new, g_old, state.v)
    return PageState(v, params)
