"""Fused experiment engine (DESIGN.md §2): compiled-loop cache, the
Common-Sample coin stream, and vmapped multi-seed scenario grids.

The training loops in :mod:`repro.core.decbyzpg` / :mod:`repro.core.byzpg`
are single ``jax.lax.scan`` programs over iterations (one fixed-shape step,
coin drawn *inside* the scan from a folded PRNG stream, stacked on-device
histories).  This module supplies the layers above them:

* ``compiled(key, build)`` — process-wide cache of jitted loops keyed by
  the static configuration, so scenario sweeps compile each loop shape
  exactly once (the legacy per-step harness re-jitted on every call);
* ``seed_keys(seed)`` — the canonical PRNG split shared by single runs,
  legacy loops, and grid lanes, so a grid lane for seed *s* replays the
  exact key stream of ``run_*(cfg(seed=s))``;
* ``ScenarioGrid`` / ``run_grid`` — declare a scenario product over
  **any** config fields (``axes={"K": (1, 5), "eta": (1e-3, 5e-3),
  "attack": ("none", "large_noise(sigma=10)")}``) and a seed batch; seeds
  are ``jax.vmap``-ed through the fused loop in one device program per
  scenario, and results come back keyed by a per-grid ``Scenario`` tuple
  with mean ± CI summaries;
* ``Experiment`` — the declarative front door
  (``Experiment(algo=..., env=..., T=..., seeds=..., axes=..., **base)``
  with ``.run()``, ``.summary()``, ``.to_json()``), built on the component
  registry (DESIGN.md §4) so every string is a parseable component spec.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
from typing import Callable, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.registry import Spec, resolve

# ---------------------------------------------------------------------------
# Common-Sample coin + canonical key derivation
# ---------------------------------------------------------------------------


class SeedKeys(NamedTuple):
    init: jnp.ndarray     # policy initialization
    loop: jnp.ndarray     # per-iteration step keys (split into T)
    coin: jnp.ndarray     # PAGE coin stream (folded per iteration)


def seed_keys(seed) -> SeedKeys:
    """Canonical (init, loop, coin) key split from an integer seed.

    Traceable: ``seed`` may be a traced int32, so per-seed streams can be
    derived *inside* a vmapped grid lane.
    """
    base = jax.random.PRNGKey(seed)
    return SeedKeys(*jax.random.split(base, 3))


def page_coin(coin_key, t, p: float):
    """Common-Sample coin c_t ~ Be(p), forced to 1 at t=0, drawn from the
    per-iteration fold of the shared coin key (identical for every honest
    agent — the paper's shared-PRNG Common-Sample primitive)."""
    draw = jax.random.bernoulli(jax.random.fold_in(coin_key, t), p)
    return (t == 0) | draw


# ---------------------------------------------------------------------------
# Compiled-loop cache
# ---------------------------------------------------------------------------

_COMPILED: dict = {}


def compiled(key, build: Callable):
    """Return the cached compiled callable for ``key``, building (and
    jitting) it on first use.  Keys must capture everything static about
    the loop: algorithm, env identity, config minus seed, T, batch size.

    When host telemetry is on (:func:`repro.obs.enable`) each lookup
    emits a hit/miss record on the ``engine.cache`` stream and the build
    runs under a trace span; off, the only cost is one ``enabled()``
    check."""
    fn = _COMPILED.get(key)
    if fn is not None:
        if obs.enabled():
            obs.record("engine.cache", event="hit", key=repr(key))
        return fn
    if obs.enabled():
        obs.record("engine.cache", event="miss", key=repr(key))
        with obs.host_span("engine.build", key=repr(key)):
            fn = build()
    else:
        fn = build()
    _COMPILED[key] = fn
    return fn


def clear_cache() -> None:
    _COMPILED.clear()


def compile_count() -> int:
    """Number of cached compiled programs (one per static signature) —
    the stable surface for benchmarks/tests asserting compile counts."""
    return len(_COMPILED)


def static_key(cfg):
    """Config hashed without its seed (seeds are data, not program)."""
    return dataclasses.replace(cfg, seed=0)


def donate_args(*argnums):
    """Carry-donation argnums, empty on CPU by policy: donation is a
    device-memory play, and host allocations are cheap enough that the
    reuse is not worth coupling callers to invalidated input buffers.
    (The ``repro.analysis`` donation audit compiles each site with its
    donation *forced* so aliasing is still validated on CPU CI.)"""
    return argnums if jax.default_backend() != "cpu" else ()


# ---------------------------------------------------------------------------
# Algorithm definitions (registry namespace "algo")
# ---------------------------------------------------------------------------


class AlgoDef(NamedTuple):
    """What the engine needs from an algorithm: its config dataclass, the
    fused-loop/carry builders, and the single-run entry points. Algorithm
    modules register one under ``register("algo", name)``.

    ``traced_fields`` names the config scalars the algorithm's builders
    accept as *traced operands* (via their ``traced=`` mapping) instead of
    baked-in Python constants — the static/traced split behind lane
    batching.  Entries may be derived properties (``switch_p``); only
    real dataclass fields are blanked in the static representative.

    ``build_window`` is the windowed form of ``build_loop`` (DESIGN.md
    §12): ``build_window(env, cfg, traced=...)`` returns
    ``window(carry, ts, step_keys, coin_key) -> (carry, hist_chunk)``
    scanning an arbitrary contiguous slice of the iteration stream with
    an explicit carry, so chained windows replay the uninterrupted loop
    bit for bit.  ``carry_hist`` names the history key the one-shot loop
    fills from the final ``carry[0]`` (``"theta"`` for DecByzPG's agent
    stack, ``"vec"`` for ByzPG's server iterate) — window assembly puts
    it back."""
    config_cls: type
    build_loop: Callable
    init_carry: Callable
    run: Callable
    run_legacy: Callable
    traced_fields: Tuple[str, ...] = ()
    build_window: Optional[Callable] = None
    carry_hist: str = "theta"


def _algo(name) -> AlgoDef:
    return resolve("algo", name)


# ---------------------------------------------------------------------------
# Static/traced config split (lane batching)
# ---------------------------------------------------------------------------


def traced_value(traced, name: str, default):
    """The traced operand for ``name`` when lane batching supplies one,
    else the config's plain value (builders call this for every scalar in
    their algorithm's ``traced_fields``)."""
    if traced is None:
        return default
    return traced.get(name, default)


def traced_spec_kwargs(traced, namespace: str) -> dict:
    """Traced component kwargs for ``namespace`` (stored under
    ``"<namespace>.<kwarg>"``), ready to pass as ``resolve`` context so a
    factory receives them as array operands."""
    prefix = namespace + "."
    return {k[len(prefix):]: v for k, v in (traced or {}).items()
            if k.startswith(prefix)}


def lane_split(cfg, traced_fields):
    """Split a config into ``(static_cfg, traced_names, traced_values)``.

    ``static_cfg`` is the *lane-group representative*: the config with its
    seed zeroed, every traced dataclass field blanked, and batchable
    attack kwargs stripped from the attack Spec — two scenarios that
    differ only in traced scalars map to the same (hashable) static
    representative and therefore share one compiled program.
    ``traced_names``/``traced_values`` are the matching flat operand
    vector: the algorithm's ``traced_fields`` (derived properties like
    ``switch_p`` read but not blanked) followed by each batchable
    component field's traced-marked kwargs as ``"<namespace>.<kwarg>"``
    (attacks and aggregators — e.g. ``rfa(nu=…)`` sweeps lane-batch).
    """
    from repro.core.registry import REGISTRY
    traced = {name: float(getattr(cfg, name)) for name in traced_fields}
    fields = {f.name for f in dataclasses.fields(cfg)}
    repl = {name: 0.0 for name in traced_fields if name in fields}
    if "switch_p" in traced and "p" in fields:
        # p reaches the program only through the traced switch_p, so
        # p=None (default B/N) and an explicit equal p share a signature
        repl["p"] = None
    # component spec fields whose registry namespace marks traced_kwargs;
    # field name == namespace for both of them
    for ns in ("attack", "aggregator"):
        if ns in fields:
            static_spec, kw = REGISTRY.split_traced(ns, getattr(cfg, ns))
            repl[ns] = static_spec
            for k, v in sorted(kw.items()):
                traced[f"{ns}.{k}"] = v
    static_cfg = dataclasses.replace(cfg, seed=0, **repl)
    names = tuple(traced)
    return static_cfg, names, tuple(traced[n] for n in names)


# ---------------------------------------------------------------------------
# Scenario grids over arbitrary config axes
# ---------------------------------------------------------------------------


class Scenario(NamedTuple):
    """Legacy five-axis scenario key. Grids with other axes key results by
    a dynamically built namedtuple (``scenario_key``); namedtuples compare
    and hash as plain tuples, so positional lookups interoperate."""
    K: int
    n_byz: int
    attack: str
    aggregator: str
    agreement: str


_LEGACY_AXES = ("K", "n_byz", "attack", "aggregator", "agreement")
_LEGACY_DEFAULTS = {"K": (13,), "n_byz": (0,), "attack": ("none",),
                    "aggregator": ("rfa",), "agreement": ("mda",)}


def scenario_key(names) -> type:
    """Keyed-tuple class for one grid's axis names. Equality/hashing are
    tuple-based, so keys from different grids (or plain tuples) with the
    same values in the same order compare equal."""
    return collections.namedtuple("Scenario", tuple(names))


def _as_axis(values) -> tuple:
    return values if isinstance(values, tuple) else \
        tuple(values) if isinstance(values, (list, range)) else (values,)


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """Cartesian scenario axes × a vmapped seed batch.

    Axes sweep **any** config field: ``axes={"eta": (1e-3, 5e-3),
    "attack": ("none", "large_noise(sigma=10)")}``. The five historical
    axes remain available as keyword fields; constructing a grid with only
    those (or with none) reproduces the historical five-axis product with
    its old defaults. When an ``axes`` mapping is given, it alone defines
    the sweep unless legacy fields are also set, in which case the five
    legacy axes (defaults filled) are extended/overridden by ``axes``.

    Every axis combination becomes one compiled device program (cached per
    static shape); the ``seeds`` axis is vmapped inside it.
    """
    seeds: Tuple[int, ...] = (0, 1, 2)
    K: Optional[Tuple[int, ...]] = None
    n_byz: Optional[Tuple[int, ...]] = None
    attack: Optional[Tuple] = None
    aggregator: Optional[Tuple] = None
    agreement: Optional[Tuple] = None
    axes: Optional[Mapping] = None

    def resolved_axes(self) -> dict:
        """Axis name -> tuple of values, in scenario-key order."""
        legacy = {n: _as_axis(getattr(self, n)) for n in _LEGACY_AXES
                  if getattr(self, n) is not None}
        extra = {k: _as_axis(v) for k, v in dict(self.axes or {}).items()}
        if self.axes is not None and not legacy:
            return extra
        return {**_LEGACY_DEFAULTS, **legacy, **extra}

    def explicit_axes(self) -> set:
        """Axis names the caller actually asked for (vs legacy defaults
        filled in for the historical five-axis grid shape)."""
        return ({n for n in _LEGACY_AXES if getattr(self, n) is not None}
                | set(dict(self.axes or {})))

    def scenarios(self):
        """Yield one keyed Scenario tuple per axis combination. For a
        legacy-style grid this unpacks exactly like the historical
        ``(K, n_byz, attack, aggregator, agreement)`` 5-tuple; use
        ``._asdict()`` for the ``{axis: value}`` mapping."""
        axes = self.resolved_axes()
        key_cls = scenario_key(axes)
        for combo in itertools.product(*axes.values()):
            yield key_cls(*combo)


def seed_batch_loop(env, cfg, T: int, n_seeds: int, algo="decbyzpg"):
    """Compiled ``seeds (S,) int32 -> history dict`` with every per-seed
    run (init + full T-iteration fused loop) vmapped into one program."""
    algo = Spec.of(algo)
    a = _algo(algo)
    key = ("grid", algo, env.name, env.horizon, static_key(cfg), T, n_seeds)

    def build():
        loop = a.build_loop(env, cfg, T)

        def one_seed(seed):
            ks = seed_keys(seed)
            carry = a.init_carry(env, cfg, ks.init)
            return loop(*carry, jax.random.split(ks.loop, T), ks.coin)

        return jax.jit(jax.vmap(one_seed))

    return compiled(key, build)


def lane_batch_loop(env, static_cfg, T: int, traced_names, n_rows: int,
                    algo="decbyzpg"):
    """Compiled flattened lane×seed batch: ``(vals (R, n_traced), seeds
    (R,) int32) -> history dict`` with leading axis R = lanes × seeds.

    One program serves every scenario that shares ``static_cfg``'s static
    signature: each row derives its own PRNG streams from its seed and
    overrides the traced scalars (eta, gamma, switch_p, batchable attack
    kwargs, ...) with its slice of ``vals``, so an L-point scalar sweep ×
    S seeds is a single compile and a single dispatch. The flattened
    batch axis is sharded over the ``lane_mesh`` when the row count
    divides the device count (single device: identity layout).
    """
    from repro.distributed.sharding import (lane_mesh, lane_out_sharding,
                                            lane_sharding)
    algo = Spec.of(algo)
    a = _algo(algo)
    names = tuple(traced_names)
    mesh = lane_mesh()
    sharding = lane_sharding(mesh, n_rows)
    key = ("lanes", algo, env.name, env.horizon, static_key(static_cfg),
           names, T, n_rows, None if sharding is None else mesh.size)

    def build():
        def one(vals, seed):
            # an algorithm with no traced fields keeps the historical
            # build_loop(env, cfg, T) contract — don't pass traced=
            loop = a.build_loop(env, static_cfg, T,
                                traced=dict(zip(names, vals))) \
                if names else a.build_loop(env, static_cfg, T)
            ks = seed_keys(seed)
            carry = a.init_carry(env, static_cfg, ks.init)
            return loop(*carry, jax.random.split(ks.loop, T), ks.coin)

        batched = jax.vmap(one)
        if sharding is None:
            return jax.jit(batched)
        return jax.jit(batched, in_shardings=(sharding, sharding),
                       out_shardings=lane_out_sharding(mesh, n_rows))

    return compiled(key, build)


# ---------------------------------------------------------------------------
# Windowed execution (sweep service, DESIGN.md §12)
# ---------------------------------------------------------------------------


def window_slices(T: int, windows: int) -> tuple:
    """Split ``[0, T)`` into ``windows`` contiguous ``(start, stop)``
    slices, near-equal with the remainder spread over the leading
    windows — at most two distinct widths, so a windowed run compiles at
    most two window programs regardless of W."""
    if not 1 <= windows <= T:
        raise ValueError(f"windows must be in [1, T={T}], got {windows}")
    base, rem = divmod(T, windows)
    out, start = [], 0
    for w in range(windows):
        stop = start + base + (1 if w < rem else 0)
        out.append((start, stop))
        start = stop
    return tuple(out)


def lane_init_loop(env, static_cfg, n_rows: int, algo="decbyzpg"):
    """Compiled ``seeds (R,) int32 -> carry stack``: each row's algorithm
    carry (theta stack, optimizer state, ...) from its canonical init
    key, vmapped over the flattened lane×seed batch.  Row r's carry is
    exactly what :func:`lane_batch_loop` builds internally for the same
    seed — the entry point of the windowed execution path."""
    from repro.distributed.sharding import (lane_mesh, lane_out_sharding,
                                            lane_sharding)
    algo = Spec.of(algo)
    a = _algo(algo)
    mesh = lane_mesh()
    sharding = lane_sharding(mesh, n_rows)
    key = ("lanes_init", algo, env.name, env.horizon,
           static_key(static_cfg), n_rows,
           None if sharding is None else mesh.size)

    def build():
        def one(seed):
            return a.init_carry(env, static_cfg, seed_keys(seed).init)

        batched = jax.vmap(one)
        if sharding is None:
            return jax.jit(batched)
        return jax.jit(batched, in_shardings=(sharding,),
                       out_shardings=lane_out_sharding(mesh, n_rows))

    return compiled(key, build)


def lane_window_loop(env, static_cfg, T: int, traced_names, W: int,
                     n_rows: int, algo="decbyzpg"):
    """Compiled window step ``(carry, vals (R, n), seeds (R,), ts (W,))
    -> (carry, hist chunk)`` over the flattened lane×seed batch.

    ``ts`` holds the window's *absolute* iteration indices as traced
    data, so the cache key carries no offset: every width-W window of a
    T-iteration run shares one compiled program.  Each row re-derives
    its full-T step-key stream from its seed and gathers the ``ts``
    slice, so chaining the windows of :func:`window_slices` replays the
    exact key stream of the uninterrupted :func:`lane_batch_loop` scan —
    bit for bit (``T`` stays in the key because the stream length is
    baked into the split)."""
    from repro.distributed.sharding import (lane_mesh, lane_out_sharding,
                                            lane_sharding)
    algo = Spec.of(algo)
    a = _algo(algo)
    if a.build_window is None:
        raise ValueError(
            f"algorithm {algo.canonical()!r} registers no build_window; "
            f"windowed execution needs the explicit-carry builder")
    names = tuple(traced_names)
    mesh = lane_mesh()
    sharding = lane_sharding(mesh, n_rows)
    key = ("lanes_window", algo, env.name, env.horizon,
           static_key(static_cfg), names, T, W, n_rows,
           None if sharding is None else mesh.size)

    def build():
        def one(carry, vals, seed, ts):
            window = a.build_window(env, static_cfg,
                                    traced=dict(zip(names, vals))) \
                if names else a.build_window(env, static_cfg)
            ks = seed_keys(seed)
            step_keys = jax.random.split(ks.loop, T)[ts]
            return window(carry, ts, step_keys, ks.coin)

        batched = jax.vmap(one, in_axes=(0, 0, 0, None))
        if sharding is None:
            return jax.jit(batched)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as _P
        repl = NamedSharding(mesh, _P())
        out = lane_out_sharding(mesh, n_rows)
        return jax.jit(batched,
                       in_shardings=(sharding, sharding, sharding, repl),
                       out_shardings=(out, out))

    return compiled(key, build)


def seed_init_loop(env, cfg, n_seeds: int, algo="decbyzpg"):
    """Windowed counterpart of :func:`seed_batch_loop`'s init half:
    compiled ``seeds (S,) -> carry stack`` for one scenario config."""
    algo = Spec.of(algo)
    a = _algo(algo)
    key = ("grid_init", algo, env.name, env.horizon, static_key(cfg),
           n_seeds)

    def build():
        def one_seed(seed):
            return a.init_carry(env, cfg, seed_keys(seed).init)

        return jax.jit(jax.vmap(one_seed))

    return compiled(key, build)


def seed_window_loop(env, cfg, T: int, W: int, n_seeds: int,
                     algo="decbyzpg"):
    """Windowed counterpart of :func:`seed_batch_loop`: compiled
    ``(carry, seeds (S,), ts (W,)) -> (carry, hist chunk)`` — the
    per-scenario (lanes=False) form of :func:`lane_window_loop`, with
    the same offset-free cache key and bit-identical chaining."""
    algo = Spec.of(algo)
    a = _algo(algo)
    if a.build_window is None:
        raise ValueError(
            f"algorithm {algo.canonical()!r} registers no build_window; "
            f"windowed execution needs the explicit-carry builder")
    key = ("grid_window", algo, env.name, env.horizon, static_key(cfg),
           T, W, n_seeds)

    def build():
        window = a.build_window(env, cfg)

        def one_seed(carry, seed, ts):
            ks = seed_keys(seed)
            return window(carry, ts, jax.random.split(ks.loop, T)[ts],
                          ks.coin)

        return jax.jit(jax.vmap(one_seed, in_axes=(0, 0, None)))

    return compiled(key, build)


def lane_carry_struct(env, static_cfg, n_rows: int, algo="decbyzpg"):
    """Shape/dtype skeleton of the lane carry stack via ``jax.eval_shape``
    — no compile, no cache entry — for use as a checkpoint restore
    template when resuming a window mid-T."""
    a = _algo(Spec.of(algo))

    def one(seed):
        return a.init_carry(env, static_cfg, seed_keys(seed).init)

    return jax.eval_shape(jax.vmap(one),
                          jax.ShapeDtypeStruct((n_rows,), jnp.int32))


def assemble_hist(carry, chunks, algo="decbyzpg") -> dict:
    """Stitch window hist chunks (leading row axis, time axis 1) and the
    final carry back into the one-shot loop's history dict: concatenated
    per-iteration histories plus the algorithm's ``carry_hist`` key
    (final ``carry[0]``, e.g. the theta stack) — :func:`summarize`-ready
    and bit-identical to the uninterrupted loop's output."""
    a = _algo(Spec.of(algo))
    hist = {a.carry_hist: np.asarray(carry[0])}
    for k in chunks[0]:
        hist[k] = np.concatenate([np.asarray(c[k]) for c in chunks],
                                 axis=1)
    return hist


def _pad_rows(x, n_pad: int):
    """Pad a leading row axis to ``n_pad`` by repeating the last row:
    pad rows are valid (redundant) programs whose outputs are sliced off
    before summaries, letting uneven lane×seed batches still shard over
    the lane mesh (DESIGN.md §12)."""
    n = x.shape[0]
    if n == n_pad:
        return x
    pad = jnp.broadcast_to(x[-1:], (n_pad - n,) + x.shape[1:])
    return jnp.concatenate([x, pad], axis=0)


def summarize(hist: dict, cfg) -> dict:
    """Host-side statistics for one scenario's (S, T) seed batch."""
    out = {k: np.asarray(v) for k, v in hist.items()}
    coins = out.pop("coins")
    out["samples"] = np.cumsum(np.where(coins, cfg.N, cfg.B), axis=-1)
    rets = out["returns"]
    S = rets.shape[0]
    sem = (rets.std(axis=0, ddof=1) / np.sqrt(S)) if S > 1 \
        else np.zeros(rets.shape[-1])
    out["returns_mean"] = rets.mean(axis=0)
    out["returns_ci95"] = 1.96 * sem
    final = rets[:, -3:].mean(axis=-1)
    out["final_return_mean"] = float(final.mean())
    out["final_return_ci95"] = float(
        1.96 * final.std(ddof=1) / np.sqrt(S)) if S > 1 else 0.0
    if "diameter" in out:
        # the paper's Δ₂ agreement diagnostic, reported alongside returns
        diam = out["diameter"]
        out["diameter_mean"] = diam.mean(axis=0)
        out["final_diameter_mean"] = float(diam[:, -1].mean())
    if "rejected" in out:
        # telemetry plane (cfg.telemetry): aggregator-as-detector tally
        # of per-round rejected masks vs the configured Byzantine set
        out["grad_norm_mean"] = out["grad_norm"].mean(axis=0)
        out["aggregator_confusion"] = obs.confusion_tally(
            out["rejected"], getattr(cfg, "n_byz", 0))
    return out


def _check_override(cfg_before, cfg_after, assign: dict) -> None:
    """An ``override`` hook may derive non-axis fields from axis values,
    but must not mutate an axis field itself — the result would silently
    diverge from the Scenario key it is filed under."""
    changed = [n for n in assign
               if getattr(cfg_after, n) != getattr(cfg_before, n)]
    if changed:
        raise ValueError(
            f"override mutated swept axis field(s) {changed}: the config "
            f"would no longer match its Scenario key {assign}; sweep the "
            f"desired values as an axis instead")


def grid_scenarios(grid: ScenarioGrid, algo="decbyzpg",
                   override: Optional[Callable] = None,
                   base: Optional[dict] = None):
    """Resolve a grid into ``(axes, [(scenario_key, cfg), ...])`` — the
    scenario construction shared by :func:`run_grid` and ``repro.sweep``:
    axis validation, base-field merging, and the ``override`` hook with
    its axis-mutation check.  Deterministic order (itertools.product over
    the axis mapping), so a resumed sweep re-derives the identical
    scenario list."""
    a = _algo(Spec.of(algo))
    base = dict(base or {})
    cfg_cls = a.config_cls
    fields = {f.name for f in dataclasses.fields(cfg_cls)}
    axes = grid.resolved_axes()
    # legacy-default axes a config doesn't know (e.g. "agreement" for
    # ByzPG) stay in the key but are dropped from the config, as the
    # historical five-axis grid did; explicitly requested axes must exist.
    unknown = ((set(base) | (set(axes) & grid.explicit_axes()))
               - fields)
    if unknown:
        raise TypeError(f"unknown {cfg_cls.__name__} fields: "
                        f"{sorted(unknown)}")
    overlap = set(base) & set(axes)
    explicit_overlap = overlap & grid.explicit_axes()
    if explicit_overlap:
        raise TypeError(f"fields both swept and fixed: "
                        f"{sorted(explicit_overlap)}")
    # base may pin an axis the grid only holds as a legacy default — the
    # pinned value becomes that axis's single point (and its key value)
    for n in overlap:
        axes[n] = (base.pop(n),)
    key_cls = scenario_key(axes)
    scenarios = []
    for combo in itertools.product(*axes.values()):
        assign = {k: v for k, v in zip(axes, combo) if k in fields}
        cfg = cfg_cls(**{**base, **assign})
        if override is not None:
            cfg2 = override(cfg)
            _check_override(cfg, cfg2, assign)
            cfg = cfg2
        scenarios.append((key_cls(*combo), cfg))
    return axes, scenarios


def lane_groups(scenarios, algo="decbyzpg") -> dict:
    """Group ``(scenario, cfg)`` pairs by lane-static signature
    (:func:`lane_split`): ``{(static_cfg, names): [(scn, cfg, vals)]}``
    in first-appearance order.  One group is both the unit of
    compilation for lane batching and the unit of checkpointing for the
    sweep service (``repro.sweep``)."""
    a = _algo(Spec.of(algo))
    groups: dict = {}
    for scn, cfg in scenarios:
        static_cfg, names, vals = lane_split(cfg, a.traced_fields)
        groups.setdefault((static_cfg, names), []).append((scn, cfg, vals))
    return groups


def lane_operands(members, seeds, n_pad: int):
    """Flattened ``(vals (R, n), seeds (R,))`` device operands for one
    lane group's members × the seed batch, padded to ``n_pad`` rows
    (:func:`_pad_rows`).  Traced values go float64 host-side and are
    canonicalized by ``jnp.asarray`` to the ambient float dtype (f32 by
    default, f64 under jax_enable_x64) so the operands match what
    ``lanes=False`` bakes in as Python constants."""
    S = len(seeds)
    vals = np.asarray([m[2] for m in members], np.float64)
    vals_flat = _pad_rows(jnp.asarray(np.repeat(vals, S, axis=0)), n_pad)
    seeds_flat = _pad_rows(jnp.tile(seeds, len(members)), n_pad)
    return vals_flat, seeds_flat


def run_grid(env, grid: ScenarioGrid, T: int, algo="decbyzpg",
             override: Optional[Callable] = None, lanes: bool = True,
             **base) -> dict:
    """Run every scenario in ``grid`` for ``T`` iterations.

    ``base`` sets non-axis config fields (N, B, eta, kappa, ...);
    ``override(cfg) -> cfg`` applies per-scenario adjustments to
    *non-axis* fields derived from axis values (e.g. fig2's kappa=0 naive
    baseline) — mutating a swept axis field raises, since the config would
    silently diverge from its Scenario key. Returns ``{Scenario: summary
    dict}`` with per-seed histories plus mean ± 95% CI curves, keyed by
    the grid's keyed tuple over its axis names.

    With ``lanes=True`` (default) scenarios are grouped by static
    signature (:func:`lane_split`) and each group runs as **one** compiled
    lane-batched program over the flattened lane×seed batch — an L-point
    scalar sweep (eta, gamma, a batchable attack sigma, ...) is one
    compile and one dispatch instead of L. When the flattened row count
    does not divide the lane-mesh device count, the batch is padded with
    masked duplicate rows (sliced off before summaries) so uneven groups
    still shard. ``lanes=False`` keeps the historical per-scenario
    dispatch (one :func:`seed_batch_loop` per combination) — the baseline
    ``bench_engine`` measures against.
    """
    _, scenarios = grid_scenarios(grid, algo=algo, override=override,
                                  base=base)
    seeds = jnp.asarray(grid.seeds, jnp.int32)
    if not lanes:
        results = {}
        for si, (scn, cfg) in enumerate(scenarios):
            if obs.enabled():
                obs.progress(f"run_grid {si + 1}/{len(scenarios)}: "
                             f"{dict(scn._asdict())}",
                             scenario=si, total=len(scenarios))
            loop = seed_batch_loop(env, cfg, T, len(grid.seeds), algo)
            with obs.host_span("run_grid.scenario", scenario=si):
                hist = jax.block_until_ready(loop(seeds))
            results[scn] = summarize(hist, cfg)
        return results
    # group scenario lanes by static signature: scalar-only axes collapse
    # into one compiled program per group, seeds stay vmapped inside
    from repro.distributed.sharding import lane_mesh, padded_rows
    groups = lane_groups(scenarios, algo=algo)
    mesh = lane_mesh()
    S = len(grid.seeds)
    results = {}
    for gi, ((static_cfg, names), members) in enumerate(groups.items()):
        L = len(members)
        rows = L * S
        n_pad = padded_rows(mesh, rows)
        before = compile_count()
        loop = lane_batch_loop(env, static_cfg, T, names, n_pad, algo)
        fresh = compile_count() > before    # first dispatch will compile
        if obs.enabled():
            obs.progress(f"run_grid group {gi + 1}/{len(groups)}: "
                         f"{L} lane(s) x {S} seed(s)"
                         + (" [compiling]" if fresh else " [cached]"),
                         group=gi, lanes=L, seeds=S, fresh_compile=fresh)
        vals_flat, seeds_flat = lane_operands(members, seeds, n_pad)
        with obs.host_span("run_grid.group", group=gi, lanes=L,
                           rows=rows, fresh_compile=fresh):
            hist = jax.block_until_ready(loop(vals_flat, seeds_flat))
        for i, (scn, cfg, _) in enumerate(members):
            # the per-scenario slice never reaches the pad rows (i < L)
            lane = {k: v[i * S:(i + 1) * S] for k, v in hist.items()}
            results[scn] = summarize(lane, cfg)
    return {scn: results[scn] for scn, _ in scenarios}


# ---------------------------------------------------------------------------
# Declarative Experiment API
# ---------------------------------------------------------------------------


def _axis_str(v) -> str:
    """Canonical display form of one scenario-axis value."""
    return v.canonical() if isinstance(v, Spec) else str(v)


def _axis_eq(a, b) -> bool:
    """Axis-value equality with Spec/string interchangeability: a Spec
    matches its spec string (and vice versa)."""
    if a == b:
        return True
    if isinstance(a, Spec) or isinstance(b, Spec):
        try:
            return Spec.of(a) == Spec.of(b)
        except Exception:
            return False
    return False


class ExperimentResult:
    """Results of one :class:`Experiment` run: a mapping from scenario key
    (keyed tuple over the experiment's axis names) to summary dict, plus
    JSON/plaintext reporting."""

    def __init__(self, meta: dict, axes: dict, results: dict):
        self.meta = meta
        self.axes = axes
        self.results = results

    def __getitem__(self, key):
        return self.results[key]

    def __iter__(self):
        return iter(self.results)

    def __len__(self):
        return len(self.results)

    def items(self):
        return self.results.items()

    def keys(self):
        return self.results.keys()

    def sel(self, **axes):
        """The unique scenario matching the given axis values, e.g.
        ``res.sel(aggregator="rfa")``. Spec-valued axes match their
        string/canonical forms interchangeably. Under-specified queries
        raise a ``KeyError`` naming the still-free axes (and their
        values) instead of dumping every scenario tuple."""
        names = list(self.axes)
        bad = set(axes) - set(names)
        if bad:
            raise KeyError(f"{sorted(bad)} are not sweep axes of this "
                           f"experiment; axes: {sorted(names)}")
        matches = [s for s in self.results
                   if all(_axis_eq(getattr(s, k), v)
                          for k, v in axes.items())]
        if len(matches) == 1:
            return self.results[matches[0]]
        query = ", ".join(f"{k}={_axis_str(v)}" for k, v in axes.items())
        if not matches:
            raise KeyError(
                f"sel({query}) matches no scenario; axis values: "
                + "; ".join(f"{k} in {[_axis_str(v) for v in vals]}"
                            for k, vals in self.axes.items()))
        free = [k for k in names if k not in axes and
                len({_axis_str(getattr(s, k)) for s in matches}) > 1]
        raise KeyError(
            f"sel({query}) is under-specified: {len(matches)} scenarios "
            f"match; also constrain the free axis(es) "
            + "; ".join(f"{k} in {sorted({_axis_str(getattr(s, k)) for s in matches})}"
                        for k in free))

    @staticmethod
    def scenario_name(scn) -> str:
        """Stable ``"axis=value,..."`` name: Spec-valued entries render as
        their canonical spec string, so the name is identical whether the
        axis value was given as a Spec or its string form."""
        if not scn:
            return "base"
        return ",".join(f"{k}={_axis_str(v)}"
                        for k, v in zip(scn._fields, scn))

    def summary(self) -> dict:
        """Compact per-scenario statistics keyed by ``"axis=value,..."``."""
        out = {}
        for scn, r in self.results.items():
            entry = {
                "final_return_mean": r["final_return_mean"],
                "final_return_ci95": r["final_return_ci95"],
                "samples_per_agent": float(
                    np.asarray(r["samples"])[:, -1].mean()),
            }
            # Δ₂ diagnostic; absent for algos without agreement (ByzPG)
            if "final_diameter_mean" in r:
                entry["honest_diameter_final"] = r["final_diameter_mean"]
            # aggregator-as-Byzantine-detector forensics (cfg.telemetry)
            if "aggregator_confusion" in r:
                conf = r["aggregator_confusion"]
                entry["aggregator_precision"] = conf["precision"]
                entry["aggregator_recall"] = conf["recall"]
            out[self.scenario_name(scn)] = entry
        return out

    def to_json(self, path=None, curves: bool = True):
        """JSON document (written to ``path`` when given) with experiment
        metadata and per-scenario summaries; ``curves`` includes the
        mean ± CI return curves (per-seed parameter arrays are omitted)."""
        doc = {"experiment": self.meta, "scenarios": []}
        summ = self.summary()
        for scn, r in self.results.items():
            entry = {"scenario": dict(zip(scn._fields, [
                v.canonical() if isinstance(v, Spec) else v for v in scn])),
                **summ[self.scenario_name(scn)]}
            if curves:
                entry["returns_mean"] = np.asarray(
                    r["returns_mean"]).tolist()
                entry["returns_ci95"] = np.asarray(
                    r["returns_ci95"]).tolist()
                entry["samples_mean"] = np.asarray(
                    r["samples"]).mean(axis=0).tolist()
            doc["scenarios"].append(entry)
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=2)
        return doc


class Experiment:
    """Declarative experiment over the fused engine (DESIGN.md §4).

    ::

        Experiment(algo="decbyzpg", env="cartpole(horizon=100)", T=40,
                   seeds=4, axes={"eta": (1e-2, 2e-2),
                                  "attack": ("none",
                                             "large_noise(sigma=10)")},
                   K=13, n_byz=3, N=20, B=4).run()

    ``axes`` sweeps any config fields (values are component spec strings,
    Specs, or plain values); remaining keyword arguments fix base config
    fields. ``seeds`` is a tuple of seeds or an int (``range(seeds)``);
    ``env`` is an ``Env`` or an env spec resolved through the registry.
    ``override(cfg) -> cfg`` derives non-axis fields per scenario and is
    validated against axis mutation exactly like :func:`run_grid` (it is
    the same check — ``run()`` executes through ``run_grid``).
    """

    def __init__(self, algo="decbyzpg", env="cartpole", T: int = 50,
                 seeds=(0, 1, 2), axes: Optional[Mapping] = None,
                 override: Optional[Callable] = None, lanes: bool = True,
                 **base):
        self.algo = Spec.of(algo)
        self.env_spec = env
        self.T = int(T)
        self.seeds = tuple(range(seeds)) if isinstance(seeds, int) \
            else tuple(seeds)
        self.axes = {k: _as_axis(v) for k, v in dict(axes or {}).items()}
        self.override = override
        self.lanes = lanes
        self.base = base
        self._result: Optional[ExperimentResult] = None

    @property
    def env(self):
        from repro.rl.envs import make_env
        return make_env(self.env_spec)

    def run(self, force: bool = False) -> ExperimentResult:
        """Execute (or return the cached) run. Compiled loops are cached
        process-wide, so ``run(force=True)`` re-executes without
        recompiling."""
        if self._result is not None and not force:
            return self._result
        env = self.env
        grid = ScenarioGrid(seeds=self.seeds, axes=self.axes)
        results = run_grid(env, grid, self.T, algo=self.algo,
                           override=self.override, lanes=self.lanes,
                           **self.base)
        meta = {"algo": self.algo.canonical(),
                "env": (Spec.of(self.env_spec).canonical()
                        if isinstance(self.env_spec, (str, Spec))
                        else env.name),
                "T": self.T, "seeds": list(self.seeds),
                "axes": {k: [v.canonical() if isinstance(v, Spec) else v
                             for v in vals]
                         for k, vals in self.axes.items()},
                "base": {k: (v.canonical() if isinstance(v, Spec) else
                             repr(v) if not isinstance(
                                 v, (int, float, bool, str, type(None)))
                             else v)
                         for k, v in self.base.items()},
                # marker only: the hook itself is code and can't round-trip
                "override": (getattr(self.override, "__qualname__",
                                     repr(self.override))
                             if self.override is not None else None)}
        self._result = ExperimentResult(meta, self.axes, results)
        return self._result

    def summary(self) -> dict:
        return self.run().summary()

    def to_json(self, path=None, curves: bool = True):
        return self.run().to_json(path, curves=curves)
