"""Fused experiment engine (DESIGN.md §2): compiled-loop cache, the
Common-Sample coin stream, and vmapped multi-seed scenario grids.

The training loops in :mod:`repro.core.decbyzpg` / :mod:`repro.core.byzpg`
are single ``jax.lax.scan`` programs over iterations (one fixed-shape step,
coin drawn *inside* the scan from a folded PRNG stream, stacked on-device
histories).  This module supplies the layers above them:

* ``compiled(key, build)`` — process-wide cache of jitted loops keyed by
  the static configuration, so scenario sweeps compile each loop shape
  exactly once (the legacy per-step harness re-jitted on every call);
* ``seed_keys(seed)`` — the canonical PRNG split shared by single runs,
  legacy loops, and grid lanes, so a grid lane for seed *s* replays the
  exact key stream of ``run_*(cfg(seed=s))``;
* ``ScenarioGrid`` / ``run_grid`` — declare a scenario product over
  (K, n_byz, attack, aggregator, agreement) and a seed batch; seeds are
  ``jax.vmap``-ed through the fused loop in one device program per
  scenario, and results come back as a structured tree with mean ± CI.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Common-Sample coin + canonical key derivation
# ---------------------------------------------------------------------------


class SeedKeys(NamedTuple):
    init: jnp.ndarray     # policy initialization
    loop: jnp.ndarray     # per-iteration step keys (split into T)
    coin: jnp.ndarray     # PAGE coin stream (folded per iteration)


def seed_keys(seed) -> SeedKeys:
    """Canonical (init, loop, coin) key split from an integer seed.

    Traceable: ``seed`` may be a traced int32, so per-seed streams can be
    derived *inside* a vmapped grid lane.
    """
    base = jax.random.PRNGKey(seed)
    return SeedKeys(*jax.random.split(base, 3))


def page_coin(coin_key, t, p: float):
    """Common-Sample coin c_t ~ Be(p), forced to 1 at t=0, drawn from the
    per-iteration fold of the shared coin key (identical for every honest
    agent — the paper's shared-PRNG Common-Sample primitive)."""
    draw = jax.random.bernoulli(jax.random.fold_in(coin_key, t), p)
    return (t == 0) | draw


# ---------------------------------------------------------------------------
# Compiled-loop cache
# ---------------------------------------------------------------------------

_COMPILED: dict = {}


def compiled(key, build: Callable):
    """Return the cached compiled callable for ``key``, building (and
    jitting) it on first use.  Keys must capture everything static about
    the loop: algorithm, env identity, config minus seed, T, batch size."""
    fn = _COMPILED.get(key)
    if fn is None:
        fn = _COMPILED[key] = build()
    return fn


def clear_cache() -> None:
    _COMPILED.clear()


def static_key(cfg):
    """Config hashed without its seed (seeds are data, not program)."""
    return dataclasses.replace(cfg, seed=0)


def donate_args(*argnums):
    """Carry-donation argnums, empty on CPU where donation is unimplemented
    (it would only emit a "donated buffers were not usable" warning)."""
    return argnums if jax.default_backend() != "cpu" else ()


# ---------------------------------------------------------------------------
# Scenario grids
# ---------------------------------------------------------------------------


class Scenario(NamedTuple):
    K: int
    n_byz: int
    attack: str
    aggregator: str
    agreement: str


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """Cartesian scenario axes × a vmapped seed batch.

    Every combination of the five axes becomes one compiled device program
    (cached per static shape); the ``seeds`` axis is vmapped inside it.
    """
    seeds: Tuple[int, ...] = (0, 1, 2)
    K: Tuple[int, ...] = (13,)
    n_byz: Tuple[int, ...] = (0,)
    attack: Tuple[str, ...] = ("none",)
    aggregator: Tuple[str, ...] = ("rfa",)
    agreement: Tuple[str, ...] = ("mda",)

    def scenarios(self):
        return itertools.product(self.K, self.n_byz, self.attack,
                                 self.aggregator, self.agreement)


def _algo(name: str):
    if name == "decbyzpg":
        from repro.core import decbyzpg as m
        return m.DecByzPGConfig, m.build_decbyzpg_loop, m.init_decbyzpg_carry
    if name == "byzpg":
        from repro.core import byzpg as m
        return m.ByzPGConfig, m.build_byzpg_loop, m.init_byzpg_carry
    raise KeyError(f"unknown algorithm {name!r}")


def seed_batch_loop(env, cfg, T: int, n_seeds: int, algo: str = "decbyzpg"):
    """Compiled ``seeds (S,) int32 -> history dict`` with every per-seed
    run (init + full T-iteration fused loop) vmapped into one program."""
    _, build_loop, init_carry = _algo(algo)
    key = ("grid", algo, env.name, env.horizon, static_key(cfg), T, n_seeds)

    def build():
        loop = build_loop(env, cfg, T)

        def one_seed(seed):
            ks = seed_keys(seed)
            carry = init_carry(env, cfg, ks.init)
            return loop(*carry, jax.random.split(ks.loop, T), ks.coin)

        return jax.jit(jax.vmap(one_seed))

    return compiled(key, build)


def summarize(hist: dict, cfg) -> dict:
    """Host-side statistics for one scenario's (S, T) seed batch."""
    out = {k: np.asarray(v) for k, v in hist.items()}
    coins = out.pop("coins")
    out["samples"] = np.cumsum(np.where(coins, cfg.N, cfg.B), axis=-1)
    rets = out["returns"]
    S = rets.shape[0]
    sem = (rets.std(axis=0, ddof=1) / np.sqrt(S)) if S > 1 \
        else np.zeros(rets.shape[-1])
    out["returns_mean"] = rets.mean(axis=0)
    out["returns_ci95"] = 1.96 * sem
    final = rets[:, -3:].mean(axis=-1)
    out["final_return_mean"] = float(final.mean())
    out["final_return_ci95"] = float(
        1.96 * final.std(ddof=1) / np.sqrt(S)) if S > 1 else 0.0
    return out


def run_grid(env, grid: ScenarioGrid, T: int, algo: str = "decbyzpg",
             override: Optional[Callable] = None, **base) -> dict:
    """Run every scenario in ``grid`` for ``T`` iterations.

    ``base`` sets non-axis config fields (N, B, eta, kappa, ...);
    ``override(cfg) -> cfg`` applies per-scenario adjustments that are
    functions of the axis values (e.g. fig2's kappa=0 naive baseline).
    Returns ``{Scenario: summary dict}`` with per-seed histories plus
    mean ± 95% CI curves.
    """
    cfg_cls, _, _ = _algo(algo)
    fields = {f.name for f in dataclasses.fields(cfg_cls)}
    unknown = set(base) - fields
    if unknown:
        raise TypeError(f"unknown {cfg_cls.__name__} fields: {sorted(unknown)}")
    seeds = jnp.asarray(grid.seeds, jnp.int32)
    results = {}
    for K, n_byz, attack, aggregator, agreement in grid.scenarios():
        axes = {"K": K, "n_byz": n_byz, "attack": attack,
                "aggregator": aggregator, "agreement": agreement}
        cfg = cfg_cls(**{k: v for k, v in {**base, **axes}.items()
                         if k in fields})
        if override is not None:
            cfg = override(cfg)
        loop = seed_batch_loop(env, cfg, T, len(grid.seeds), algo)
        hist = jax.block_until_ready(loop(seeds))
        results[Scenario(K, n_byz, attack, aggregator, agreement)] = \
            summarize(hist, cfg)
    return results
