"""Core algorithm layer. Exports resolve lazily (PEP 562) so importing
any one submodule — e.g. ``repro.core.registry``, which leaf modules like
``repro.optim.optimizers`` depend on — does not pull in the whole
algorithm stack and create an import cycle."""
import importlib

_EXPORTS = {
    "get_aggregator": "repro.core.aggregators",
    "avg_agree": "repro.core.agreement",
    "gda_mean": "repro.core.agreement",
    "honest_diameter": "repro.core.agreement",
    "mda_mean": "repro.core.agreement",
    "get_attack": "repro.core.attacks",
    "is_env_level": "repro.core.attacks",
    "per_receiver": "repro.core.attacks",
    "ByzPGConfig": "repro.core.byzpg",
    "run_byzpg": "repro.core.byzpg",
    "run_byzpg_legacy": "repro.core.byzpg",
    "DecByzPGConfig": "repro.core.decbyzpg",
    "run_decbyzpg": "repro.core.decbyzpg",
    "run_decbyzpg_legacy": "repro.core.decbyzpg",
    "Experiment": "repro.core.engine",
    "ExperimentResult": "repro.core.engine",
    "Scenario": "repro.core.engine",
    "ScenarioGrid": "repro.core.engine",
    "run_grid": "repro.core.engine",
    "REGISTRY": "repro.core.registry",
    "Spec": "repro.core.registry",
    "SpecError": "repro.core.registry",
    "register": "repro.core.registry",
    "resolve": "repro.core.registry",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.core' has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(module), name)


def __dir__():
    return __all__
