from repro.core.aggregators import get_aggregator
from repro.core.agreement import avg_agree, gda_mean, honest_diameter, mda_mean
from repro.core.attacks import ATTACKS, get_attack, per_receiver
from repro.core.byzpg import ByzPGConfig, run_byzpg, run_byzpg_legacy
from repro.core.decbyzpg import (DecByzPGConfig, run_decbyzpg,
                                 run_decbyzpg_legacy)
from repro.core.engine import Scenario, ScenarioGrid, run_grid
