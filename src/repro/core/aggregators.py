"""(α, C_ra)-robust aggregation (paper Def. 1, App. A.2).

All aggregators map a stacked input ``x: (K, d)`` to ``(d,)``. Production
implementations per the paper: **bucketing ∘ Krum** (α_max = 1/4) and
**bucketing ∘ RFA** (α_max = 1/2, smoothed Weiszfeld). Coordinate-wise
median / trimmed mean are provided as additional baselines.

The aggregation hot path (pairwise distances, Krum scoring, the Weiszfeld
iteration, the coordinate-wise trimmed mean) routes through the kernel
suite behind ``repro.kernels.dispatch`` (DESIGN.md §6): compiled Pallas on
TPU, the jnp oracles elsewhere, overridable globally or per call.
Distances decompose over model shards so the distributed path psums the
K×K matrix instead of gathering vectors (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.registry import Spec, register, resolve
from repro.kernels.dispatch import get_kernel


def _sharded(x, sharded: Optional[bool]) -> bool:
    """Route to the flat sharded execution layer? Explicit intent wins;
    otherwise detect a NamedSharding splitting the trailing (parameter)
    axis — eager-only, like every other trace-time dispatch decision."""
    if sharded is not None:
        return bool(sharded)
    from repro.distributed.aggregation import dim_sharded
    return dim_sharded(x)


def pairwise_sq_dists(x: jnp.ndarray, backend: Optional[str] = None,
                      sharded: Optional[bool] = None) -> jnp.ndarray:
    """(K, d) -> (K, K) squared euclidean distances (dispatched kernel; a
    D-sharded input takes the local-Gram + K² psum path instead)."""
    if _sharded(x, sharded):
        from repro.distributed import aggregation as agg_lib
        return agg_lib.flat_sq_dists(x)
    return get_kernel("pairwise_dist")(x, backend=backend)


# ---------------------------------------------------------------------------
# Base aggregators
# ---------------------------------------------------------------------------

def mean(x, key=None):
    return jnp.mean(x, axis=0)


def krum(x, n_byz: int, key=None, m: int = 1,
         sharded: Optional[bool] = None):
    """(Multi-)Krum [34]: score_i = Σ_{j in closest K-n_byz-2} ||x_j - x_i||²;
    return the mean of the m lowest-scoring inputs.

    Scoring routes through the ``krum_score`` kernel (Gram pass + on-device
    rank network); only the final m-way selection runs as generic jnp. A
    D-sharded input instead runs the flat sharded layer (local-shard Gram
    + K² psum, selection by weighted sum — no row gather).
    """
    if _sharded(x, sharded):
        from repro.distributed import aggregation as agg_lib
        return agg_lib.flat_krum(x, n_byz, m=m)
    K = x.shape[0]
    n_near = max(K - n_byz - 2, 1)
    scores = get_kernel("krum_score")(x, n_near)
    if m == 1:
        return x[jnp.argmin(scores)]
    _, idx = jax.lax.top_k(-scores, m)
    return jnp.mean(x[idx], axis=0)


def rfa(x, key=None, n_iter: int = 32, nu=1e-6,
        sharded: Optional[bool] = None):
    """Robust Federated Averaging [35]: geometric median via smoothed
    Weiszfeld [36] — dispatched to the Gram-space ``rfa`` kernel; a
    D-sharded input runs the same weight-space iteration on the psum'd
    Gram matrix (``flat_rfa``)."""
    if _sharded(x, sharded):
        from repro.distributed import aggregation as agg_lib
        return agg_lib.flat_rfa(x, n_iter=n_iter, nu=nu)
    return get_kernel("rfa")(x, n_iter=n_iter, nu=nu)


def coordinate_median(x, key=None):
    return jnp.median(x, axis=0)


def trimmed_mean(x, n_byz: int, key=None, sharded: Optional[bool] = None):
    """Coordinate-wise: drop the n_byz largest and smallest per coordinate.

    Routes through the dispatched ``trimmed_mean`` kernel; D-sharded
    inputs run the oracle body shard-locally (coordinate-wise reduces
    commute with D-sharding).
    """
    if _sharded(x, sharded):
        from repro.distributed import aggregation as agg_lib
        return agg_lib.flat_trimmed_mean(x, n_byz)
    return get_kernel("trimmed_mean")(x, n_byz)


def centered_clip(x, key=None, tau: float = 1.0, n_iter: int = 5,
                  center=None):
    """Centered clipping [29]: iteratively re-center on the clipped mean
    v <- v + mean_i clip(x_i - v, tau). Robust for alpha < 1/2 under
    bounded variance; tau should scale with the honest std."""
    # start from the coordinate-wise median: the clipped-mean iteration
    # moves at most tau per step, so a mean start can stay stuck near a
    # large-outlier attack
    v = jnp.median(x, axis=0) if center is None else center

    def body(v, _):
        diff = x - v
        norm = jnp.linalg.norm(diff, axis=1, keepdims=True)
        clipped = diff * jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        return v + jnp.mean(clipped, axis=0), None

    v, _ = jax.lax.scan(body, v, None, length=n_iter)
    return v


def suspicion_scores(spec, x: jnp.ndarray, n_byz: int) -> jnp.ndarray:
    """Per-sender Byzantine-suspicion scores ``(K,)`` — the telemetry
    forensics signal behind :func:`rejection_mask` (DESIGN.md §8).

    Aggregators with an explicit selection expose it directly: Krum's
    score (high = far from the closest-neighbor mass) and the cw
    trimmed-mean family's per-coordinate trim fraction. Everything else
    (mean, rfa, cwmed, bucketing wrappers) falls back to the distance
    from the coordinate-wise median — a deterministic, key-free proxy
    for "how far outside the honest cluster this sender landed". It is
    a diagnostic view, not the aggregation itself (bucketed variants
    score the raw messages, not the bucket means).
    """
    spec = Spec.of(spec)
    K = x.shape[0]
    if spec.name == "krum":
        n_near = max(K - max(n_byz, 1) - 2, 1)
        return get_kernel("krum_score")(x, n_near)
    if spec.name in ("trimmed_mean", "cwtm"):
        nt = max(n_byz, 1)
        # rank of each sender per coordinate; trimmed = in either tail
        ranks = jnp.argsort(jnp.argsort(x, axis=0), axis=0)
        trimmed = (ranks < nt) | (ranks >= K - nt)
        return jnp.mean(trimmed.astype(x.dtype), axis=1)
    med = jnp.median(x, axis=0)
    return jnp.sqrt(jnp.sum((x - med[None]) ** 2, axis=1))


def rejection_mask(spec, x: jnp.ndarray, n_byz: int) -> jnp.ndarray:
    """(K,) bool: the ``n_byz`` most-suspicious senders this round, per
    :func:`suspicion_scores`. Cardinality is pinned to the configured
    tolerance so the confusion tally vs the true Byzantine set
    (``repro.obs.confusion_tally``) has comparable precision/recall
    semantics across aggregators. All-False when ``n_byz == 0``."""
    K = x.shape[0]
    if n_byz <= 0:
        return jnp.zeros((K,), bool)
    scores = suspicion_scores(spec, x, n_byz)
    _, idx = jax.lax.top_k(scores, n_byz)
    return jnp.zeros((K,), bool).at[idx].set(True)


def resilient_momentum_update(agg: Callable, momenta, beta: float,
                              grads, key=None):
    """One step of resilient averaging of momentums [23]: workers keep
    local momenta m_i <- beta m_i + (1-beta) g_i; the server robustly
    aggregates the momenta (variance shrinks by (1-beta), improving any
    (alpha, C_ra)-aggregator's bound). Returns (new_momenta, direction).
    momenta/grads: (K, d)."""
    new_m = beta * momenta + (1.0 - beta) * grads
    return new_m, agg(new_m, key)


# ---------------------------------------------------------------------------
# Bucketing wrapper [33]
# ---------------------------------------------------------------------------

def bucketing(inner: Callable, x, key, bucket_size: int):
    """Randomly permute inputs, average buckets of ``bucket_size``, then apply
    the inner aggregator to the bucket means (Karimireddy et al. [33]).

    The key is split between the permutation and the inner aggregator so a
    key-consuming inner (e.g. DnC-style subsampling) gets fresh randomness
    instead of silently receiving none.
    """
    K, d = x.shape
    n_buckets = -(-K // bucket_size)
    k_perm, k_inner = jax.random.split(key)
    perm = jax.random.permutation(k_perm, K)
    pad = n_buckets * bucket_size - K
    # pad by repeating the first permuted entries so every bucket is full
    idx = jnp.concatenate([perm, perm[:pad]]) if pad else perm
    means = jnp.mean(x[idx].reshape(n_buckets, bucket_size, d), axis=1)
    return inner(means, key=k_inner)


# ---------------------------------------------------------------------------
# Registry factories — every factory returns ``agg(x, key) -> (d,)``
# ---------------------------------------------------------------------------

def _lemma3_bucket_size(K: int, n_byz: int, alpha_max: float) -> int:
    """Bucket size per Lemma 3: ``floor(alpha_max / alpha)`` with
    ``alpha = n_byz / K`` (bucketing disabled when n_byz == 0)."""
    if n_byz == 0:
        return 1
    return max(1, int(alpha_max / max(n_byz / K, 1e-9)))


@register("aggregator", "mean")
def _mean_factory():
    return lambda x, key=None: mean(x)


# ``static_kwargs`` records the traced-eligibility audit (DESIGN.md §12):
# numeric kwargs that MUST stay baked into the program shape — loop trip
# counts (n_iter), top-k / reshape sizes (m, s), and host-side bucket
# arithmetic (alpha_max) — so the audit test can prove every scalar is
# deliberately classified and sweep lane groups are as wide as they can be.

@register("aggregator", "krum", static_kwargs=("m", "alpha_max"))
def _krum_factory(K, n_byz, m: int = 1, alpha_max: float = 0.25,
                  sharded: Optional[bool] = None):
    bs = _lemma3_bucket_size(K, n_byz, alpha_max)
    if bs == 1:
        return lambda x, key=None: krum(x, n_byz=max(n_byz, 1), m=m,
                                        sharded=sharded)
    inner = functools.partial(krum, n_byz=max(1, -(-K // bs) // 4), m=m,
                              sharded=sharded)
    return lambda x, key: bucketing(inner, x, key, bs)


@register("aggregator", "rfa", traced_kwargs=("nu",),
          static_kwargs=("n_iter", "alpha_max"))
def _rfa_factory(K, n_byz, n_iter: int = 32, nu=1e-6,
                 alpha_max: float = 0.5, sharded: Optional[bool] = None):
    bs = _lemma3_bucket_size(K, n_byz, alpha_max)
    inner = functools.partial(rfa, n_iter=n_iter, nu=nu, sharded=sharded)
    if bs == 1:
        return lambda x, key=None: inner(x)
    return lambda x, key: bucketing(inner, x, key, bs)


@register("aggregator", "cwmed")
def _cwmed_factory():
    return lambda x, key=None: coordinate_median(x)


@register("aggregator", "centered_clip", traced_kwargs=("tau",),
          static_kwargs=("n_iter",))
def _centered_clip_factory(tau=1.0, n_iter: int = 5):
    return lambda x, key=None: centered_clip(x, tau=tau, n_iter=n_iter)


@register("aggregator", "trimmed_mean")
def _trimmed_mean_factory(n_byz, sharded: Optional[bool] = None):
    return lambda x, key=None: trimmed_mean(x, max(n_byz, 1),
                                            sharded=sharded)


@register("aggregator", "bucketing", static_kwargs=("s",))
def _bucketing_factory(K, n_byz, inner, s: int = 2):
    """Explicit bucketing with a fixed bucket size ``s`` around any inner
    aggregator spec, e.g. ``bucketing(inner=rfa(n_iter=64), s=2)``.

    The inner spec is resolved against the bucket means: K becomes the
    bucket count and n_byz becomes 0 so the inner component doesn't apply
    Lemma-3 auto-bucketing a second time.
    """
    n_buckets = -(-K // s)
    inner_fn = resolve("aggregator", inner, K=n_buckets, n_byz=0)
    return lambda x, key: bucketing(inner_fn, x, key, s)


def get_aggregator(name, K: int, n_byz: int,
                   alpha_max: Optional[float] = None) -> Callable:
    """Resolve an aggregator spec (name, spec string, or Spec) against the
    federation shape. Kept as the historical entry point; new code can call
    ``registry.resolve("aggregator", spec, K=K, n_byz=n_byz)`` directly."""
    ctx = {"K": K, "n_byz": n_byz}
    if alpha_max is not None:
        # context, not a spec kwarg: silently ignored (as historically) by
        # factories that don't take alpha_max; explicit spec kwargs win
        ctx["alpha_max"] = alpha_max
    return resolve("aggregator", Spec.of(name), **ctx)
