"""ByzPG — centralized Byzantine fault-tolerant federated PG (Algorithm 1).

Faithful simulator of the paper's Algorithm 1 over K agents:

* coin ``c_t ~ Be(p)``; on c=1 (or t=0) all workers sample N trajectories at
  θ_t and send PG estimates, robustly aggregated at the server;
* on c=0 the **server alone** samples B trajectories and applies the PAGE
  correction ``v_t = ĝ_B(θ_t) + v_{t-1} − ĝ_B^{ω_{θ_t}}(θ_{t-1})`` with
  importance sampling (the paper's key deviation from Byz-VR-MARINA);
* Byzantine agents' messages are replaced by the configured attack
  (RandomAction corrupts their environment interaction instead).

The paper's experiments apply Adam to the PAGE direction (App. D) — we
support both plain ascent (`optimizer="sgd"`, faithful to Algorithm 1 line
12) and Adam.

Like DecByzPG, the T-loop is one fused ``lax.scan`` (DESIGN.md §2): the
coin comes from a folded PRNG stream inside the scan and every step keeps
the fixed (K, max(N, B)) trajectory shape, with estimator weights masking
small steps down to B.  The server's small-batch stream is the last agent
slot (slot K-1 is honest for any tolerated n_byz < K; in the centralized
protocol all workers hold the same θ_t, so slot K-1's trajectories are
exactly a fresh server sample).  ``run_byzpg_legacy`` keeps the per-step
dispatch harness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import attacks as attacks_lib
from repro.core import engine
from repro.core.aggregators import rejection_mask
from repro.core.registry import normalize_spec_fields, register, resolve
from repro.core.tree import ravel
from repro.optim.optimizers import get_optimizer
from repro.rl.gradient import grad_estimate, weighted_grad_estimate
from repro.rl.policy import policy_unraveler, resolve_policy
from repro.rl.rollout import batch_return, sample_batch

_SPEC_FIELDS = ("attack", "aggregator", "estimator", "optimizer", "policy")


@dataclasses.dataclass(frozen=True)
class ByzPGConfig:
    K: int = 13
    n_byz: int = 0
    attack: object = "none"         # str | Spec, normalized to Spec
    aggregator: object = "rfa"
    N: int = 50                 # large batch
    B: int = 4                  # small batch
    p: Optional[float] = None   # switch prob; default B/N
    eta: float = 5e-3
    gamma: float = 0.999
    estimator: object = "gpomdp"
    policy: object = "mlp"      # policy spec (see repro.rl.policy)
    activation: str = "relu"
    hidden: tuple = (16, 16)
    optimizer: object = "adam"
    baseline: float = 0.0
    seed: int = 0
    telemetry: bool = False     # static (in static_key): in-loop obs taps
    # + per-round rejected-agent masks; off = exact seed program

    def __post_init__(self):
        normalize_spec_fields(self, _SPEC_FIELDS)

    @property
    def switch_p(self) -> float:
        return self.p if self.p is not None else self.B / self.N


def _optimizer(cfg):
    return get_optimizer(cfg.optimizer, cfg.eta)


def init_byzpg_carry(env, cfg: ByzPGConfig, k_init):
    """(θ (d,), θ_prev, v_prev, opt_state) — traceable for grid lanes."""
    vec0 = ravel(resolve_policy(cfg, env).init(k_init))[0]
    opt_state = _optimizer(cfg).init(vec0)
    return vec0, jnp.array(vec0), jnp.zeros_like(vec0), opt_state


def build_byzpg_step(env, cfg: ByzPGConfig, traced=None):
    """One fixed-shape iteration ``step(carry, (t, key), coin_key)``.

    ``traced`` maps lane-traced scalar names (see
    :func:`repro.core.decbyzpg.build_decbyzpg_step`) to array operands
    overriding the config's baked-in floats.
    """
    eta = engine.traced_value(traced, "eta", cfg.eta)
    gamma = engine.traced_value(traced, "gamma", cfg.gamma)
    baseline = engine.traced_value(traced, "baseline", cfg.baseline)
    switch_p = engine.traced_value(traced, "switch_p", cfg.switch_p)
    policy = resolve_policy(cfg, env)
    unravel, _ = policy_unraveler(policy)
    logits_spec = policy.logits
    byz_mask = jnp.asarray(np.arange(cfg.K) < cfg.n_byz)
    env_level = attacks_lib.is_env_level(cfg.attack)
    attack = resolve("attack", cfg.attack,
                     **engine.traced_spec_kwargs(traced, "attack"))
    agg = resolve("aggregator", cfg.aggregator, K=cfg.K, n_byz=cfg.n_byz,
                  **engine.traced_spec_kwargs(traced, "aggregator"))
    opt = get_optimizer(cfg.optimizer, eta)
    scales = jnp.where(byz_mask & env_level, 0.0, 1.0)

    M = max(cfg.N, cfg.B)
    idx = jnp.arange(M)
    w_large = jnp.where(idx < cfg.N, 1.0 / cfg.N, 0.0)
    w_small = jnp.where(idx < cfg.B, 1.0 / cfg.B, 0.0)
    server = cfg.K - 1          # honest slot backing the server's stream

    def step(carry, xs, coin_key):
        vec, prev_vec, v_prev, opt_state = carry
        t, key = xs
        coin = engine.page_coin(coin_key, t, switch_p)
        w = jnp.where(coin, w_large, w_small)
        k_traj, k_att, k_agg = jax.random.split(key, 3)
        params = unravel(vec)
        prev = unravel(prev_vec)

        def one(k, scale):
            traj = sample_batch(env, params, k, M, logits_spec,
                                logit_scale=scale)
            g = ravel(grad_estimate(params, traj, gamma, baseline,
                                    cfg.estimator, logits_spec,
                                    sample_weights=w))[0]
            g_old = ravel(weighted_grad_estimate(
                prev, params, traj, gamma, baseline,
                cfg.estimator, logits_spec,
                sample_weights=w_small))[0]
            return g, g_old, jnp.sum(w * batch_return(traj))

        with obs.named_phase("byzpg.estimate", cfg.telemetry):
            g, g_old, rets = jax.vmap(one)(jax.random.split(k_traj, cfg.K),
                                           scales)
        with obs.named_phase("byzpg.aggregate", cfg.telemetry):
            msgs = attack(g, byz_mask, k_att)
            v_large = agg(msgs, k_agg)
        # small step: w == w_small, so g[server] is exactly ĝ_B(θ_t) on the
        # server's fresh batch and g_old[server] the IS estimate at θ_prev.
        v_page = g[server] + v_prev - g_old[server]
        v = jnp.where(coin, v_large, v_page)
        new_vec, opt_state = opt.update(v, opt_state, vec)
        honest_ret = jnp.sum(jnp.where(byz_mask, 0.0, rets)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        ret = jnp.where(coin, honest_ret, rets[server])
        if not cfg.telemetry:
            return (new_vec, vec, v, opt_state), (ret, coin)
        # observers only (no extra PRNG consumption): the aggregation is
        # live on large rounds; small rounds still score the attacked
        # worker messages the server would have received
        norms = jnp.linalg.norm(g, axis=1)
        grad_norm = jnp.sum(jnp.where(byz_mask, 0.0, norms)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        rejected = rejection_mask(cfg.aggregator, msgs, cfg.n_byz)
        obs.tap("byzpg", t=t, coin=coin, honest_return=ret,
                grad_norm=grad_norm, rejected=rejected)
        return (new_vec, vec, v, opt_state), (ret, coin, grad_norm,
                                              rejected)

    return step


def build_byzpg_window(env, cfg: ByzPGConfig, traced=None):
    """Window program (DESIGN.md §12): scan the step over an arbitrary
    slice of the iteration stream with the explicit
    ``(θ, θ_prev, v_prev, opt_state)`` carry; ``ts`` are absolute
    iteration indices, ``step_keys`` the matching slice of the full
    ``split(loop_key, T)`` stream. Chained windows reproduce the
    uninterrupted scan bit for bit."""
    step = build_byzpg_step(env, cfg, traced)

    def window(carry, ts, step_keys, coin_key):
        carry, ys = jax.lax.scan(
            lambda c, xs: step(c, xs, coin_key), carry, (ts, step_keys))
        hist = {"returns": ys[0], "coins": ys[1]}
        if cfg.telemetry:
            hist["grad_norm"], hist["rejected"] = ys[2], ys[3]
        return carry, hist

    return window


def build_byzpg_loop(env, cfg: ByzPGConfig, T: int, traced=None):
    """Pure fused loop: one ``lax.scan`` over T iterations — the
    single-window [0, T) instance of :func:`build_byzpg_window`."""
    window = build_byzpg_window(env, cfg, traced)

    def loop(vec0, prev_vec0, v0, opt_state0, step_keys, coin_key):
        (vec, _, _, _), hist = window((vec0, prev_vec0, v0, opt_state0),
                                      jnp.arange(T), step_keys, coin_key)
        return {"vec": vec, **hist}

    return loop


def fused_byzpg(env, cfg: ByzPGConfig, T: int):
    # only vec_0 aliases an output (the final vec); the other carries have
    # no same-shaped output, so donating them would be dead weight — the
    # repro.analysis donation audit enforces full aliasing
    key = ("byzpg", env.name, env.horizon, engine.static_key(cfg), T)
    return engine.compiled(key, lambda: jax.jit(
        build_byzpg_loop(env, cfg, T),
        donate_argnums=engine.donate_args(0)))


def _finalize(cfg, unravel, hist, eval_every: int) -> dict:
    coins = np.asarray(hist["coins"])
    samples = np.cumsum(np.where(coins, cfg.N, cfg.B))
    out = {"returns": np.asarray(hist["returns"])[::eval_every],
           "samples": samples[::eval_every],
           "params": unravel(hist["vec"])}
    if "rejected" in hist:
        out["grad_norm"] = np.asarray(hist["grad_norm"])
        out["rejected"] = np.asarray(hist["rejected"])
        out["aggregator_confusion"] = obs.confusion_tally(
            out["rejected"], cfg.n_byz)
    return out


def run_byzpg(env, cfg: ByzPGConfig, T: int, eval_every: int = 1):
    """Returns dict(history of honest mean returns, sampled trajectories per
    agent, final params)."""
    ks = engine.seed_keys(cfg.seed)
    unravel, _ = policy_unraveler(resolve_policy(cfg, env))
    carry = init_byzpg_carry(env, cfg, ks.init)
    loop = fused_byzpg(env, cfg, T)
    hist = jax.block_until_ready(
        loop(*carry, jax.random.split(ks.loop, T), ks.coin))
    return _finalize(cfg, unravel, hist, eval_every)


def run_byzpg_legacy(env, cfg: ByzPGConfig, T: int, eval_every: int = 1):
    """Per-step dispatch harness over the same step function (fresh jit per
    call, host sync per iteration) — kept for equivalence tests and the
    ``bench_engine`` baseline."""
    ks = engine.seed_keys(cfg.seed)
    unravel, _ = policy_unraveler(resolve_policy(cfg, env))
    carry = init_byzpg_carry(env, cfg, ks.init)
    step = jax.jit(build_byzpg_step(env, cfg))
    step_keys = jax.random.split(ks.loop, T)
    rets, coins = [], []
    for t in range(T):
        # ys grows telemetry entries under cfg.telemetry; the first two
        # are always (return, coin)
        carry, ys = step(carry, (jnp.int32(t), step_keys[t]), ks.coin)
        rets.append(float(ys[0]))
        coins.append(bool(ys[1]))
    hist = {"vec": carry[0], "returns": np.asarray(rets),
            "coins": np.asarray(coins)}
    return _finalize(cfg, unravel, hist, eval_every)


register("algo", "byzpg")(lambda: engine.AlgoDef(
    ByzPGConfig, build_byzpg_loop, init_byzpg_carry,
    run_byzpg, run_byzpg_legacy,
    traced_fields=("eta", "gamma", "baseline", "switch_p"),
    build_window=build_byzpg_window, carry_hist="vec"))
