"""ByzPG — centralized Byzantine fault-tolerant federated PG (Algorithm 1).

Faithful simulator of the paper's Algorithm 1 over K agents:

* coin ``c_t ~ Be(p)``; on c=1 (or t=0) all workers sample N trajectories at
  θ_t and send PG estimates, robustly aggregated at the server;
* on c=0 the **server alone** samples B trajectories and applies the PAGE
  correction ``v_t = ĝ_B(θ_t) + v_{t-1} − ĝ_B^{ω_{θ_t}}(θ_{t-1})`` with
  importance sampling (the paper's key deviation from Byz-VR-MARINA);
* Byzantine agents' messages are replaced by the configured attack
  (RandomAction corrupts their environment interaction instead).

The paper's experiments apply Adam to the PAGE direction (App. D) — we
support both plain ascent (`optimizer="sgd"`, faithful to Algorithm 1 line
12) and Adam.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as attacks_lib
from repro.core.aggregators import get_aggregator
from repro.core.tree import ravel, stack_ravel, unstack_unravel
from repro.optim.optimizers import get_optimizer
from repro.rl.gradient import grad_estimate, weighted_grad_estimate
from repro.rl.rollout import batch_return, sample_batch


@dataclasses.dataclass(frozen=True)
class ByzPGConfig:
    K: int = 13
    n_byz: int = 0
    attack: str = "none"
    aggregator: str = "rfa"
    N: int = 50                 # large batch
    B: int = 4                  # small batch
    p: Optional[float] = None   # switch prob; default B/N
    eta: float = 5e-3
    gamma: float = 0.999
    estimator: str = "gpomdp"
    activation: str = "relu"
    hidden: tuple = (16, 16)
    optimizer: str = "adam"
    baseline: float = 0.0
    seed: int = 0

    @property
    def switch_p(self) -> float:
        return self.p if self.p is not None else self.B / self.N


def _agent_grads(env, params, keys, cfg, scales):
    """Stacked per-agent large-batch PG estimates ṽ^(k): (K, d)."""

    def one(key, scale):
        traj = sample_batch(env, params, key, cfg.N, cfg.activation,
                            logit_scale=scale)
        g = grad_estimate(params, traj, cfg.gamma, cfg.baseline,
                          cfg.estimator, cfg.activation)
        return ravel(g)[0], jnp.mean(batch_return(traj))

    return jax.vmap(one)(keys, scales)


def run_byzpg(env, cfg: ByzPGConfig, T: int, eval_every: int = 1):
    """Returns dict(history of honest mean returns, sampled trajectories per
    agent, final params)."""
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    from repro.rl.policy import init_mlp
    params = init_mlp(k_init, (env.obs_dim, *cfg.hidden, env.n_actions))
    vec0, unravel = ravel(params)

    byz_mask = np.zeros(cfg.K, bool)
    byz_mask[:cfg.n_byz] = True       # which slots are Byzantine (H_t fixed
    byz_mask = jnp.asarray(byz_mask)  # WLOG in the sim; roles are symmetric)
    env_level = cfg.attack in attacks_lib.ENV_LEVEL_ATTACKS
    attack = attacks_lib.get_attack(cfg.attack)
    agg = get_aggregator(cfg.aggregator, cfg.K, cfg.n_byz)
    opt = get_optimizer(cfg.optimizer, cfg.eta)
    scales = jnp.where(byz_mask & env_level, 0.0, 1.0)

    @jax.jit
    def large_step(params, opt_state, key):
        k_traj, k_att, k_agg = jax.random.split(key, 3)
        tilde_v, rets = _agent_grads(env, params, jax.random.split(
            k_traj, cfg.K), cfg, scales)
        msgs = attack(tilde_v, byz_mask, k_att)
        v = agg(msgs, k_agg)
        g = unravel(v)
        new_params, opt_state = opt.update(g, opt_state, params)
        honest_ret = jnp.sum(jnp.where(byz_mask, 0.0, rets)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        return new_params, opt_state, v, honest_ret

    @jax.jit
    def small_step(params, prev_params, v_prev, opt_state, key):
        traj = sample_batch(env, params, key, cfg.B, cfg.activation)
        g_new = ravel(grad_estimate(params, traj, cfg.gamma, cfg.baseline,
                                    cfg.estimator, cfg.activation))[0]
        g_old = ravel(weighted_grad_estimate(
            prev_params, params, traj, cfg.gamma, cfg.baseline,
            cfg.estimator, cfg.activation))[0]
        v = g_new + v_prev - g_old
        new_params, opt_state = opt.update(unravel(v), opt_state, params)
        return new_params, opt_state, v, jnp.mean(batch_return(traj))

    rng = np.random.default_rng(cfg.seed + 1)   # Common-Sample coin
    opt_state = opt.init(params)
    v_prev = jnp.zeros_like(vec0)
    prev_params = params
    hist_returns, hist_samples = [], []
    n_samples = 0
    for t in range(T):
        key, k_step = jax.random.split(key)
        c = 1 if t == 0 else int(rng.random() < cfg.switch_p)
        if c:
            new_params, opt_state, v_prev, ret = large_step(
                params, opt_state, k_step)
            n_samples += cfg.N
        else:
            new_params, opt_state, v_prev, ret = small_step(
                params, prev_params, v_prev, opt_state, k_step)
            n_samples += cfg.B
        prev_params, params = params, new_params
        if t % eval_every == 0:
            hist_returns.append(float(ret))
            hist_samples.append(n_samples)
    return {"returns": hist_returns, "samples": hist_samples,
            "params": params}
