"""Pytree <-> flat-vector utilities for stacked (per-agent) parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def ravel(tree):
    """tree -> (vec (d,), unravel_fn)."""
    return ravel_pytree(tree)


def stack_ravel(stacked_tree) -> jnp.ndarray:
    """Tree with leading K axis on every leaf -> (K, d) matrix.

    Leaf order matches ``ravel`` of a single agent's tree.
    """
    leaves = jax.tree.leaves(stacked_tree)
    K = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(K, -1) for l in leaves], axis=1)


def unstack_unravel(mat: jnp.ndarray, template):
    """(K, d) matrix -> tree with leading K axis, shaped like template
    (template has NO leading K axis)."""
    leaves, treedef = jax.tree.flatten(template)
    K = mat.shape[0]
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(mat[:, off:off + n].reshape((K,) + l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
