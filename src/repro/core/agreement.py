"""Averaging agreement (paper Def. 3, App. A.3): MDA and GDA over
arbitrary gossip graphs.

``Avg-Agree_κ`` runs κ rounds of message passing; each agent selects a
large low-diameter subset of what it received and averages it. MDA (exact
minimum-diameter subset, exponential in the neighborhood size — capped at
:data:`MDA_MAX_AGENTS`) tolerates α_max = 1/4; GDA (greedy: the
⌈(1-ᾱ)·deg⌉ closest to the agent's own vector, O(deg)) tolerates
α_max = 1/5 and is the production path.

The paper's Algorithm 3 is the complete-graph case. The core here
generalizes it to any static directed topology (DESIGN.md §5): round ``r``
delivers messages only along edges, Byzantine senders may equivocate
per-receiver-edge, and selection runs over the padded fixed-shape
neighbor gather ``(K, deg_max, d)`` so everything vmaps/jits. On the
complete graph the gather table is ``arange(K)`` per row, making the
masked core *identical* to the historical all-to-all broadcast — same
ops, same PRNG stream, same numerics.

The simulator models the full Byzantine adversary including per-receiver
inconsistent messages (a ``(K_recv, K_send, d)`` attack tensor): each
receiver observes its own adversarial version only along its in-edges.
"""
from __future__ import annotations

import contextlib
import itertools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.aggregators import pairwise_sq_dists
from repro.core.registry import REGISTRY, Spec, register, resolve
from repro.kernels import dispatch
from repro.kernels.dispatch import get_kernel
from repro.topology import Topology, resolve_topology

#: Largest neighbor-multiset size ``mda_mean`` will enumerate subsets for.
#: C(n, n_keep) subsets materialize as a trace-time constant — beyond this
#: the enumeration blows up combinatorially. Note the limit applies to the
#: *neighborhood*, not K: MDA on a sparse graph (ring(k=4) has deg 5)
#: stays usable at federation sizes where the complete graph cannot.
MDA_MAX_AGENTS = 16


def _subsets(K: int, size: int) -> np.ndarray:
    """All index-subsets of [K] with given size, as a (n_subsets, size)
    static numpy array (trace-time constant)."""
    return np.array(list(itertools.combinations(range(K), size)),
                    dtype=np.int32)


def mda_mean(received: jnp.ndarray, n_keep: int) -> jnp.ndarray:
    """Exact Minimum-Diameter Averaging: received (n, d) -> (d,).

    Enumerates subsets (static at trace time) — exponential in n, per the
    paper usable only for small multisets; raises beyond
    :data:`MDA_MAX_AGENTS` instead of silently materializing C(n, n_keep)
    subset tables.
    """
    K = received.shape[0]
    if K > MDA_MAX_AGENTS:
        raise ValueError(
            f"mda_mean enumerates C(n, n_keep) subsets at trace time and "
            f"received a multiset of size {K} > MDA_MAX_AGENTS="
            f"{MDA_MAX_AGENTS}; use method='gda' or a sparser topology "
            f"(the limit applies to the neighborhood size, not K)")
    subs = jnp.asarray(_subsets(K, n_keep))              # (n_sub, n_keep)
    d2 = pairwise_sq_dists(received)
    # diameter of each subset = max pairwise distance within it
    sub_d = d2[subs[:, :, None], subs[:, None, :]]       # (n_sub, nk, nk)
    diam = jnp.max(sub_d.reshape(subs.shape[0], -1), axis=1)
    best = jnp.argmin(diam)
    return jnp.mean(received[subs[best]], axis=0)


def gda_mean(received: jnp.ndarray, own: jnp.ndarray,
             n_keep: int) -> jnp.ndarray:
    """Greedy Diameter Averaging: mean of the n_keep vectors closest to the
    agent's own vector. O(n) selection."""
    d2 = jnp.sum((received - own[None]) ** 2, axis=1)
    _, idx = jax.lax.top_k(-d2, n_keep)
    return jnp.mean(received[idx], axis=0)


class AgreementMethod(NamedTuple):
    """A resolved agreement rule. Selection methods (MDA/GDA) carry
    ``select(received, own, n_keep) -> (d,)`` plus the tolerated
    ``alpha_bar``; coordinate-wise methods instead carry ``reduce`` (a
    gossip-reduce mode) and run through the fused ``gossip_reduce``
    kernel."""
    select: Optional[Callable]
    alpha_bar: float
    reduce: Optional[str] = None
    n_trim: int = 0


@register("agreement", "mda", max_agents=MDA_MAX_AGENTS)
def _mda_factory(alpha_bar: float = 0.25):
    return AgreementMethod(lambda recv, own, n_keep: mda_mean(recv, n_keep),
                           alpha_bar)


@register("agreement", "gda")
def _gda_factory(alpha_bar: float = 0.2):
    return AgreementMethod(gda_mean, alpha_bar)


@register("agreement", "cwmean")
def _cwmean_factory():
    """Plain lazy-gossip averaging (no Byzantine tolerance) — the α = 0
    baseline, and the fastest contraction on honest graphs."""
    return AgreementMethod(None, 0.0, reduce="mean")


@register("agreement", "cwmed")
def _cwmed_factory():
    """Coordinate-wise median over each neighbor multiset (α_max = 1/2
    per coordinate under bounded dispersion)."""
    return AgreementMethod(None, 0.5, reduce="median")


@register("agreement", "cwtm")
def _cwtm_factory(n_byz: int = 0, n_trim: Optional[int] = None):
    """Coordinate-wise trimmed mean over each neighbor multiset; trims
    ``n_trim`` (default: the config's ``n_byz``) from each tail, so it
    needs ``deg_max > 2·n_trim``."""
    nt = n_byz if n_trim is None else n_trim
    return AgreementMethod(None, 0.25, reduce="trimmed", n_trim=nt)


def avg_agree(theta: jnp.ndarray, kappa: int, n_byz: int,
              byz_mask: Optional[jnp.ndarray] = None,
              method="gda",
              attack: Optional[Callable] = None,
              key: Optional[jnp.ndarray] = None,
              alpha_bar: Optional[float] = None,
              topology=None,
              kernel_backend: Optional[str] = None,
              sharded: Optional[bool] = None,
              telemetry: bool = False) -> jnp.ndarray:
    """Simulate Avg-Agree_κ over K agents (paper Algorithm 3, generalized
    to gossip graphs).

    theta: (K, d) current parameters (honest agents' entries are real; the
    Byzantine entries are ignored — Byzantines send whatever ``attack``
    produces, possibly per-receiver).
    method: agreement spec — "mda" | "gda" | "gda(alpha_bar=0.25)" |
    "cwmean" | "cwmed" | "cwtm(n_trim=2)" | Spec. The cw* methods reduce
    each neighbor multiset coordinate-wise through the fused
    ``gossip_reduce`` kernel. ``kernel_backend`` scopes the dispatch
    backend over the whole multi-round core (trace-time), so it governs
    every kernel inside — the gossip reduces and MDA's pairwise-distance
    kernel alike. When ``theta`` is D-sharded (detected eagerly, or forced
    with ``sharded=True`` from inside jit) and no backend was requested,
    the rounds run on the ``jnp`` oracles: the cw reduces are
    coordinate-wise and therefore shard-local, whereas a Pallas call would
    gather the full (K, d) stack to one device.
    attack: fn(broadcast (K,d), byz_mask, key) -> (K_recv, K_send, d) or
    (K_send, d) messages. None = honest broadcast. An active attack
    requires an explicit ``key`` — there is no silent PRNGKey(0) fallback
    (it would make attacks deterministic and identical across calls).
    topology: None (complete broadcast) | spec string/Spec | resolved
    :class:`~repro.topology.Topology`. Messages travel only along the
    graph's edges; selection runs over the padded fixed-shape neighbor
    gather, so low-degree agents see extra copies of their own value.
    Returns the (K, d) post-agreement parameters (Byzantine rows carry the
    value an honest agent in that slot would compute; callers mask them).
    ``telemetry`` labels the gossip-round body with a ``jax.named_scope``
    (profile-readable HLO metadata; off, the program text is untouched).
    """
    K, d = theta.shape
    if kernel_backend is None:
        from repro.distributed.aggregation import dim_sharded
        if dim_sharded(theta) if sharded is None else sharded:
            kernel_backend = "jnp"     # shard-local coordinate-wise rounds
    m = resolve("agreement", method, n_byz=n_byz)
    topo = resolve_topology(topology, K)
    nbr = jnp.asarray(topo.nbr_idx)                      # (K, P)
    P = topo.deg_max
    alpha_bar = alpha_bar if alpha_bar is not None else m.alpha_bar
    # never forced to include a Byzantine: n_keep <= P - n_byz (agents know
    # the tolerance bound f, as in any BFT protocol). With GDA's
    # alpha_max = 1/5 this is what makes 3-of-13 (alpha ~ 0.23) behave.
    n_keep = min(int(np.ceil((1.0 - alpha_bar) * P)), P - n_byz)
    n_keep = max(n_keep, 1)
    limit = REGISTRY.meta("agreement", method).get("max_agents")
    if limit is not None and P > limit:
        raise ValueError(
            f"agreement method {Spec.of(method).name!r} supports neighbor "
            f"multisets up to {limit}, but topology {topo.name!r} has "
            f"deg_max={P}; use 'gda' or a sparser topology")
    if byz_mask is None:
        byz_mask = jnp.zeros((K,), bool)
    if key is None:
        if attack is not None:
            raise ValueError(
                "avg_agree: an active attack requires an explicit PRNG "
                "`key` (thread one from the caller's key stream); the old "
                "silent key=None -> PRNGKey(0) fallback made attacks "
                "deterministic and identical across calls")
        # honest rounds never consume a key (attack is None), so the
        # placeholder is a raw zero key, not a PRNG stream
        key = jnp.zeros((2,), jnp.uint32)
    rows = jnp.arange(K)[:, None]

    def one_round(th, k):
        with obs.named_phase("agree.round", telemetry):
            return _round_body(th, k)

    def _round_body(th, k):
        if attack is None:
            if m.reduce is not None:
                # honest broadcast: gather + reduce fused in one kernel
                return get_kernel("gossip_reduce")(
                    th, nbr, mode=m.reduce, n_trim=m.n_trim), None
            recv = th[nbr]                               # (K, P, d)
        else:
            a = attack(th, byz_mask, k)
            if a.ndim == 3:
                # per-receiver-edge equivocation: receiver r observes its
                # own adversarial slice a[r] along its in-edges only;
                # honest senders always deliver their true value
                recv = jnp.where(byz_mask[nbr][:, :, None],
                                 a[rows, nbr], th[nbr])
            else:
                sent = jnp.where(byz_mask[:, None], a, th)
                if m.reduce is not None:
                    # consistent attack: still one shared message matrix,
                    # so the fused gather applies
                    return get_kernel("gossip_reduce")(
                        sent, nbr, mode=m.reduce, n_trim=m.n_trim), None
                recv = sent[nbr]
        if m.reduce is not None:
            return get_kernel("neighbor_reduce")(
                recv, mode=m.reduce, n_trim=m.n_trim), None
        new = jax.vmap(lambda rv, own: m.select(rv, own, n_keep)
                       )(recv, th)
        return new, None

    # backend dispatch is a trace-time decision, so scoping the scan is
    # enough to reroute every kernel the rounds touch
    ctx = (dispatch.use_backend(kernel_backend) if kernel_backend
           else contextlib.nullcontext())
    with ctx:
        theta, _ = jax.lax.scan(one_round, theta,
                                jax.random.split(key, kappa))
    return theta


def honest_diameter(theta: jnp.ndarray, honest_mask: jnp.ndarray) -> jnp.ndarray:
    """max_{i,l honest} ||θ_i - θ_l|| — the paper's Δ₂ diagnostic."""
    d2 = pairwise_sq_dists(theta)
    m = honest_mask[:, None] & honest_mask[None, :]
    return jnp.sqrt(jnp.max(jnp.where(m, d2, 0.0)))
