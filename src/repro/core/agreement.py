"""Averaging agreement (paper Def. 3, App. A.3): MDA and GDA.

``Avg-Agree_κ`` runs κ rounds of all-to-all broadcast; each agent selects a
large low-diameter subset of what it received and averages it. MDA (exact
minimum-diameter subset, exponential in K — used for K<=16) tolerates
α_max = 1/4; GDA (greedy: the ⌈(1-ᾱ)K⌉ closest to the agent's own vector,
O(K)) tolerates α_max = 1/5 and is the production path.

The simulator below models the full Byzantine adversary including
per-receiver inconsistent messages.
"""
from __future__ import annotations

import itertools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import pairwise_sq_dists
from repro.core.registry import register, resolve


def _subsets(K: int, size: int) -> np.ndarray:
    """All index-subsets of [K] with given size, as a (n_subsets, size)
    static numpy array (trace-time constant)."""
    return np.array(list(itertools.combinations(range(K), size)),
                    dtype=np.int32)


def mda_mean(received: jnp.ndarray, n_keep: int) -> jnp.ndarray:
    """Exact Minimum-Diameter Averaging: received (K, d) -> (d,).

    Enumerates subsets (static at trace time) — exponential in K, per the
    paper usable only for small K; tests use K <= 16.
    """
    K = received.shape[0]
    subs = jnp.asarray(_subsets(K, n_keep))              # (n_sub, n_keep)
    d2 = pairwise_sq_dists(received)
    # diameter of each subset = max pairwise distance within it
    sub_d = d2[subs[:, :, None], subs[:, None, :]]       # (n_sub, nk, nk)
    diam = jnp.max(sub_d.reshape(subs.shape[0], -1), axis=1)
    best = jnp.argmin(diam)
    return jnp.mean(received[subs[best]], axis=0)


def gda_mean(received: jnp.ndarray, own: jnp.ndarray,
             n_keep: int) -> jnp.ndarray:
    """Greedy Diameter Averaging: mean of the n_keep vectors closest to the
    agent's own vector. O(K) selection."""
    d2 = jnp.sum((received - own[None]) ** 2, axis=1)
    _, idx = jax.lax.top_k(-d2, n_keep)
    return jnp.mean(received[idx], axis=0)


class AgreementMethod(NamedTuple):
    """A resolved agreement selection rule: ``select(received, own, n_keep)
    -> (d,)`` plus the method's tolerated ``alpha_bar``."""
    select: Callable
    alpha_bar: float


@register("agreement", "mda")
def _mda_factory(alpha_bar: float = 0.25):
    return AgreementMethod(lambda recv, own, n_keep: mda_mean(recv, n_keep),
                           alpha_bar)


@register("agreement", "gda")
def _gda_factory(alpha_bar: float = 0.2):
    return AgreementMethod(gda_mean, alpha_bar)


def avg_agree(theta: jnp.ndarray, kappa: int, n_byz: int,
              byz_mask: Optional[jnp.ndarray] = None,
              method="gda",
              attack: Optional[Callable] = None,
              key: Optional[jnp.ndarray] = None,
              alpha_bar: Optional[float] = None) -> jnp.ndarray:
    """Simulate Avg-Agree_κ over K agents (paper Algorithm 3).

    theta: (K, d) current parameters (honest agents' entries are real; the
    Byzantine entries are ignored — Byzantines send whatever ``attack``
    produces, possibly per-receiver).
    method: agreement spec — "mda" | "gda" | "gda(alpha_bar=0.25)" | Spec.
    attack: fn(broadcast (K,d), byz_mask, key) -> (K_recv, K_send, d) or
    (K_send, d) messages. None = honest broadcast.
    Returns the (K, d) post-agreement parameters (Byzantine rows carry the
    value an honest agent in that slot would compute; callers mask them).
    """
    K, d = theta.shape
    m = resolve("agreement", method)
    alpha_bar = alpha_bar if alpha_bar is not None else m.alpha_bar
    # never forced to include a Byzantine: n_keep <= K - n_byz (agents know
    # the tolerance bound f, as in any BFT protocol). With GDA's
    # alpha_max = 1/5 this is what makes 3-of-13 (alpha ~ 0.23) behave.
    n_keep = min(int(np.ceil((1.0 - alpha_bar) * K)), K - n_byz)
    n_keep = max(n_keep, 1)
    if byz_mask is None:
        byz_mask = jnp.zeros((K,), bool)

    def one_round(th, k):
        msgs = th[None].repeat(K, axis=0)                # (recv, send, d)
        if attack is not None:
            a = attack(th, byz_mask, k)
            msgs = a if a.ndim == 3 else a[None].repeat(K, axis=0)
            # honest senders always deliver their true value
            msgs = jnp.where(byz_mask[None, :, None], msgs,
                             th[None].repeat(K, axis=0))
        new = jax.vmap(lambda recv, own: m.select(recv, own, n_keep)
                       )(msgs, th)
        return new, None

    if key is None:
        key = jax.random.PRNGKey(0)
    theta, _ = jax.lax.scan(one_round, theta, jax.random.split(key, kappa))
    return theta


def honest_diameter(theta: jnp.ndarray, honest_mask: jnp.ndarray) -> jnp.ndarray:
    """max_{i,l honest} ||θ_i - θ_l|| — the paper's Δ₂ diagnostic."""
    d2 = pairwise_sq_dists(theta)
    m = honest_mask[:, None] & honest_mask[None, :]
    return jnp.sqrt(jnp.max(jnp.where(m, d2, 0.0)))
