"""DecByzPG — decentralized Byzantine fault-tolerant federated PG
(Algorithm 2), full-fidelity K-agent simulator.

Per iteration t, every agent k:
  1. draws the common coin c_t (Common-Sample; PRNG from the shared init);
  2. samples M ∈ {N, B} local trajectories at its own θ_t^(k);
  3. forms ṽ_t^(k): a plain estimate (c=1) or the PAGE correction using its
     *realized* previous step (θ_t^(k) − θ_{t-1}^(k))/η and an
     importance-weighted estimate at θ_{t-1}^(k) (c=0);
  4. robustly aggregates everyone's (possibly Byzantine) messages;
  5. takes the step θ̃_{t+1}^(k) = θ_t^(k) + η v_t^(k);
  6. runs Avg-Agree_κ (MDA/GDA) to contract the parameter diameter.

``aggregator="mean", kappa=0`` recovers the naive Dec-PAGE-PG baseline;
``K=1`` recovers PAGE-PG — exactly the baselines of the paper's Figures 2-3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as attacks_lib
from repro.core.agreement import avg_agree, honest_diameter
from repro.core.aggregators import get_aggregator
from repro.core.tree import ravel, stack_ravel, unstack_unravel
from repro.rl.gradient import grad_estimate, weighted_grad_estimate
from repro.rl.policy import init_mlp
from repro.rl.rollout import batch_return, sample_batch


@dataclasses.dataclass(frozen=True)
class DecByzPGConfig:
    K: int = 13
    n_byz: int = 0
    attack: str = "none"
    aggregator: str = "rfa"
    agreement: str = "mda"      # mda (alpha_max=1/4, exact, K<=16) | gda
    kappa: int = 6              # Θ(log NK) agreement rounds
    per_receiver: bool = False  # Byzantines send per-receiver values
    N: int = 50
    B: int = 4
    p: Optional[float] = None
    eta: float = 5e-3
    gamma: float = 0.999
    estimator: str = "gpomdp"
    activation: str = "relu"
    hidden: tuple = (16, 16)
    baseline: float = 0.0
    optimizer: str = "adam"     # paper App. D applies Adam to the PAGE
    seed: int = 0               # direction; "sgd" = Algorithm 2 line 8

    @property
    def switch_p(self) -> float:
        return self.p if self.p is not None else self.B / self.N


def run_decbyzpg(env, cfg: DecByzPGConfig, T: int):
    """Returns history of honest mean returns, per-agent sample counts, and
    the honest parameter diameter trace (Lemma 1/2 diagnostic)."""
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    params0 = init_mlp(k_init, (env.obs_dim, *cfg.hidden, env.n_actions))
    vec0, unravel = ravel(params0)
    d = vec0.shape[0]

    byz_mask = np.zeros(cfg.K, bool)
    byz_mask[:cfg.n_byz] = True
    byz_mask = jnp.asarray(byz_mask)
    env_level = cfg.attack in attacks_lib.ENV_LEVEL_ATTACKS
    attack = attacks_lib.get_attack(cfg.attack)
    agr_attack = (attacks_lib.per_receiver(attack, cfg.K)
                  if cfg.per_receiver else attack)
    agg = get_aggregator(cfg.aggregator, cfg.K, cfg.n_byz)
    scales = jnp.where(byz_mask & env_level, 0.0, 1.0)

    def agent_estimate(theta_vec, theta_prev_vec, key, M, use_page, scale):
        params = unravel(theta_vec)
        traj = sample_batch(env, params, key, M, cfg.activation,
                            logit_scale=scale)
        g = ravel(grad_estimate(params, traj, cfg.gamma, cfg.baseline,
                                cfg.estimator, cfg.activation))[0]
        if use_page:
            prev = unravel(theta_prev_vec)
            g_old = ravel(weighted_grad_estimate(
                prev, params, traj, cfg.gamma, cfg.baseline,
                cfg.estimator, cfg.activation))[0]
            g = g + (theta_vec - theta_prev_vec) / cfg.eta - g_old
        return g, jnp.mean(batch_return(traj))

    use_adam = cfg.optimizer == "adam"

    def adam_step(v, m, s2, t):
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = b1 * m + (1 - b1) * v
        s2 = b2 * s2 + (1 - b2) * v * v
        t = t + 1.0
        upd = (m / (1 - b1 ** t)) / (jnp.sqrt(s2 / (1 - b2 ** t)) + eps)
        return upd, m, s2, t

    def make_step(M, use_page):
        @jax.jit
        def step(theta, theta_prev, opt, key):
            # theta, theta_prev: (K, d); opt: (m, s2, t) per agent
            k_traj, k_att, k_agg, k_agr = jax.random.split(key, 4)
            tilde_v, rets = jax.vmap(
                lambda tv, tp, k, s: agent_estimate(tv, tp, k, M,
                                                    use_page, s)
            )(theta, theta_prev, jax.random.split(k_traj, cfg.K), scales)
            msgs = attack(tilde_v, byz_mask, k_att)
            # every agent aggregates the same broadcast set (v^(k));
            # per-receiver inconsistency is exercised inside Avg-Agree.
            v = jax.vmap(lambda k: agg(msgs, k))(
                jax.random.split(k_agg, cfg.K))
            if use_adam:
                upd, m, s2, t = adam_step(v, *opt)
                opt = (m, s2, t)
            else:
                upd = v
            theta_tilde = theta + cfg.eta * upd
            if cfg.kappa > 0:
                theta_new = avg_agree(theta_tilde, cfg.kappa, cfg.n_byz,
                                      byz_mask, cfg.agreement, agr_attack,
                                      k_agr)
            else:
                theta_new = theta_tilde
            honest_ret = jnp.sum(jnp.where(byz_mask, 0.0, rets)) \
                / jnp.maximum(jnp.sum(~byz_mask), 1)
            diam = honest_diameter(theta_new, ~byz_mask)
            return theta_new, opt, honest_ret, diam
        return step

    large_step = make_step(cfg.N, False)
    small_step = make_step(cfg.B, True)

    rng = np.random.default_rng(cfg.seed + 1)   # Common-Sample
    theta = jnp.broadcast_to(vec0, (cfg.K, d))
    theta_prev = theta
    opt = (jnp.zeros((cfg.K, d)), jnp.zeros((cfg.K, d)), jnp.zeros(()))
    hist_returns, hist_samples, hist_diam = [], [], []
    n_samples = 0
    for t in range(T):
        key, k_step = jax.random.split(key)
        c = 1 if t == 0 else int(rng.random() < cfg.switch_p)
        step = large_step if c else small_step
        new_theta, opt, ret, diam = step(theta, theta_prev, opt, k_step)
        n_samples += cfg.N if c else cfg.B
        theta_prev, theta = theta, new_theta
        hist_returns.append(float(ret))
        hist_samples.append(n_samples)
        hist_diam.append(float(diam))
    honest_idx = int(np.argmax(~np.asarray(byz_mask)))
    return {"returns": hist_returns, "samples": hist_samples,
            "diameter": hist_diam, "params": unravel(theta[honest_idx]),
            "theta": theta}
