"""DecByzPG — decentralized Byzantine fault-tolerant federated PG
(Algorithm 2), full-fidelity K-agent simulator.

Per iteration t, every agent k:
  1. draws the common coin c_t (Common-Sample; PRNG from the shared init);
  2. samples M ∈ {N, B} local trajectories at its own θ_t^(k);
  3. forms ṽ_t^(k): a plain estimate (c=1) or the PAGE correction using its
     *realized* previous step (θ_t^(k) − θ_{t-1}^(k))/η and an
     importance-weighted estimate at θ_{t-1}^(k) (c=0);
  4. robustly aggregates everyone's (possibly Byzantine) messages;
  5. takes the step θ̃_{t+1}^(k) = θ_t^(k) + η v_t^(k);
  6. runs Avg-Agree_κ (MDA/GDA) to contract the parameter diameter.

``aggregator="mean", kappa=0`` recovers the naive Dec-PAGE-PG baseline;
``K=1`` recovers PAGE-PG — exactly the baselines of the paper's Figures 2-3.

The T-iteration loop is one fused ``jax.lax.scan`` program (DESIGN.md §2):
the coin is drawn inside the scan from a folded PRNG stream, every step
samples a fixed max(N, B)-shaped trajectory batch masked down to B by
sample weights on small steps (one compiled step, no dual-jit), the
(θ, θ_prev, opt) carry is donated, and histories come back stacked
on-device.  ``run_decbyzpg_legacy`` keeps the per-step dispatch harness
(fresh jit per call, host sync per iteration) for equivalence tests and
the ``bench_engine`` comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import attacks as attacks_lib
from repro.core import engine
from repro.core.aggregators import rejection_mask
from repro.core.agreement import avg_agree, honest_diameter
from repro.core.registry import normalize_spec_fields, register, resolve
from repro.core.tree import ravel
from repro.optim.optimizers import get_optimizer
from repro.rl.gradient import grad_estimate, weighted_grad_estimate
from repro.rl.policy import policy_unraveler, resolve_policy
from repro.rl.rollout import batch_return, sample_batch
from repro.topology import resolve_topology

_SPEC_FIELDS = ("attack", "aggregator", "agreement", "estimator",
                "optimizer", "topology", "policy")


@dataclasses.dataclass(frozen=True)
class DecByzPGConfig:
    K: int = 13
    n_byz: int = 0
    attack: object = "none"         # str | Spec, normalized to Spec
    aggregator: object = "rfa"
    agreement: object = "mda"   # mda (alpha_max=1/4, exact, K<=16) | gda
    kappa: int = 6              # Θ(log NK) agreement rounds
    per_receiver: bool = False  # Byzantines send per-receiver values
    topology: object = "complete"   # gossip graph spec (DESIGN.md §5):
    # complete | ring(k=) | torus | erdos_renyi(p=, seed=) |
    # small_world(k=, beta=, seed=) | star — static, part of static_key
    N: int = 50
    B: int = 4
    p: Optional[float] = None
    eta: float = 5e-3
    gamma: float = 0.999
    estimator: object = "gpomdp"
    policy: object = "mlp"      # policy spec: mlp(hidden=, activation=) |
    # transformer(arch=, n_layers=, ...) — resolves against env plus the
    # activation/hidden fields below (which stay the mlp defaults)
    activation: str = "relu"
    hidden: tuple = (16, 16)
    baseline: float = 0.0
    optimizer: object = "adam"  # paper App. D applies Adam to the PAGE
    seed: int = 0               # direction; "sgd" = Algorithm 2 line 8
    telemetry: bool = False     # static (in static_key): in-loop obs taps
    # + per-round rejected-agent masks; off = exact seed program

    def __post_init__(self):
        normalize_spec_fields(self, _SPEC_FIELDS)

    @property
    def switch_p(self) -> float:
        return self.p if self.p is not None else self.B / self.N


def _optimizer(cfg: DecByzPGConfig):
    return get_optimizer(cfg.optimizer, cfg.eta)


def init_decbyzpg_carry(env, cfg: DecByzPGConfig, k_init):
    """(θ_0 (K,d) common init, θ_prev, per-agent optimizer state) —
    traceable, so a grid lane can build its own carry under vmap."""
    vec0 = ravel(resolve_policy(cfg, env).init(k_init))[0]
    theta0 = jnp.tile(vec0, (cfg.K, 1))
    opt0 = jax.vmap(_optimizer(cfg).init)(theta0)
    return theta0, jnp.array(theta0), opt0


def build_decbyzpg_step(env, cfg: DecByzPGConfig, traced=None):
    """One fixed-shape iteration ``step(carry, (t, key), coin_key)``.

    Both coin branches run through the same compiled body: every agent
    samples max(N, B) trajectories and the estimator weights select the
    first N (large) or first B (small PAGE) of them, so there is exactly
    one program regardless of the coin.

    ``traced`` (lane batching, DESIGN.md §2) maps traced scalar names —
    the ``traced_fields`` registered for this algorithm plus batchable
    attack kwargs as ``"attack.<kwarg>"`` — to array operands that
    override the config's baked-in Python floats, so one compiled program
    serves every lane of a scalar sweep. ``None`` keeps the historical
    constant-folding behavior.
    """
    eta = engine.traced_value(traced, "eta", cfg.eta)
    gamma = engine.traced_value(traced, "gamma", cfg.gamma)
    baseline = engine.traced_value(traced, "baseline", cfg.baseline)
    switch_p = engine.traced_value(traced, "switch_p", cfg.switch_p)
    policy = resolve_policy(cfg, env)
    unravel, _ = policy_unraveler(policy)
    logits_spec = policy.logits
    byz_mask = jnp.asarray(np.arange(cfg.K) < cfg.n_byz)
    env_level = attacks_lib.is_env_level(cfg.attack)
    attack = resolve("attack", cfg.attack,
                     **engine.traced_spec_kwargs(traced, "attack"))
    agr_attack = (attacks_lib.per_receiver(attack, cfg.K)
                  if cfg.per_receiver else attack)
    # traced aggregator kwargs (e.g. rfa's nu) arrive as array operands so
    # an aggregator-scalar sweep shares this compiled program
    agg = resolve("aggregator", cfg.aggregator, K=cfg.K, n_byz=cfg.n_byz,
                  **engine.traced_spec_kwargs(traced, "aggregator"))
    scales = jnp.where(byz_mask & env_level, 0.0, 1.0)
    opt = get_optimizer(cfg.optimizer, eta)
    topo = resolve_topology(cfg.topology, cfg.K)

    M = max(cfg.N, cfg.B)
    idx = jnp.arange(M)
    w_large = jnp.where(idx < cfg.N, 1.0 / cfg.N, 0.0)
    w_small = jnp.where(idx < cfg.B, 1.0 / cfg.B, 0.0)

    def agent_estimate(theta_vec, theta_prev_vec, key, w, scale):
        params = unravel(theta_vec)
        prev = unravel(theta_prev_vec)
        traj = sample_batch(env, params, key, M, logits_spec,
                            logit_scale=scale)
        g = ravel(grad_estimate(params, traj, gamma, baseline,
                                cfg.estimator, logits_spec,
                                sample_weights=w))[0]
        # IS-corrected estimate at θ_prev on the small-batch slice; masked
        # out on large steps by the coin select below.
        g_old = ravel(weighted_grad_estimate(
            prev, params, traj, gamma, baseline,
            cfg.estimator, logits_spec, sample_weights=w_small))[0]
        return g, g_old, jnp.sum(w * batch_return(traj))

    def step(carry, xs, coin_key):
        theta, theta_prev, opt_state = carry  # theta: (K, d)
        t, key = xs
        coin = engine.page_coin(coin_key, t, switch_p)
        w = jnp.where(coin, w_large, w_small)
        k_traj, k_att, k_agg, k_agr = jax.random.split(key, 4)
        with obs.named_phase("decbyzpg.estimate", cfg.telemetry):
            g, g_old, rets = jax.vmap(
                lambda tv, tp, k, s: agent_estimate(tv, tp, k, w, s)
            )(theta, theta_prev, jax.random.split(k_traj, cfg.K), scales)
            page = (theta - theta_prev) / eta - g_old
            tilde_v = jnp.where(coin, g, g + page)
        with obs.named_phase("decbyzpg.aggregate", cfg.telemetry):
            msgs = attack(tilde_v, byz_mask, k_att)
            # every agent aggregates the same broadcast set (v^(k));
            # per-receiver inconsistency is exercised inside Avg-Agree.
            v = jax.vmap(lambda k: agg(msgs, k))(
                jax.random.split(k_agg, cfg.K))
        theta_tilde, opt_state = jax.vmap(opt.update)(v, opt_state, theta)
        with obs.named_phase("decbyzpg.agree", cfg.telemetry):
            if cfg.kappa > 0:
                theta_new = avg_agree(theta_tilde, cfg.kappa, cfg.n_byz,
                                      byz_mask, cfg.agreement, agr_attack,
                                      k_agr, topology=topo,
                                      telemetry=cfg.telemetry)
            else:
                theta_new = theta_tilde
        honest_ret = jnp.sum(jnp.where(byz_mask, 0.0, rets)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        diam = honest_diameter(theta_new, ~byz_mask)
        if not cfg.telemetry:
            return (theta_new, theta, opt_state), (honest_ret, coin, diam)
        # telemetry plane: observers only — no extra PRNG consumption, so
        # the returns/diameter histories are identical to the off path
        norms = jnp.linalg.norm(tilde_v, axis=1)
        grad_norm = jnp.sum(jnp.where(byz_mask, 0.0, norms)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        rejected = rejection_mask(cfg.aggregator, msgs, cfg.n_byz)
        obs.tap("decbyzpg", t=t, coin=coin, honest_return=honest_ret,
                diameter=diam, grad_norm=grad_norm, rejected=rejected)
        return (theta_new, theta, opt_state), \
            (honest_ret, coin, diam, grad_norm, rejected)

    return step


def build_decbyzpg_window(env, cfg: DecByzPGConfig, traced=None):
    """Window program (DESIGN.md §12): scan the step over an arbitrary
    contiguous slice of the iteration stream, taking and returning the
    explicit ``(θ, θ_prev, opt_state)`` carry.

    ``window(carry, ts (W,), step_keys (W, 2), coin_key) -> (carry, ys)``
    where ``ts`` are *absolute* iteration indices and ``step_keys`` the
    matching slice of the full ``split(loop_key, T)`` stream — chaining
    windows over ``[0, T)`` is the uninterrupted scan, bit for bit, and
    the compiled shape depends only on W (never on the window offset)."""
    step = build_decbyzpg_step(env, cfg, traced)

    def window(carry, ts, step_keys, coin_key):
        carry, ys = jax.lax.scan(
            lambda c, xs: step(c, xs, coin_key), carry, (ts, step_keys))
        hist = {"returns": ys[0], "coins": ys[1], "diameter": ys[2]}
        if cfg.telemetry:
            hist["grad_norm"], hist["rejected"] = ys[3], ys[4]
        return carry, hist

    return window


def build_decbyzpg_loop(env, cfg: DecByzPGConfig, T: int, traced=None):
    """Pure fused loop: one ``lax.scan`` over T iterations returning
    stacked on-device histories (no per-step host traffic) — the
    single-window [0, T) instance of :func:`build_decbyzpg_window`."""
    window = build_decbyzpg_window(env, cfg, traced)

    def loop(theta0, theta_prev0, opt0, step_keys, coin_key):
        (theta, _, _), hist = window((theta0, theta_prev0, opt0),
                                     jnp.arange(T), step_keys, coin_key)
        return {"theta": theta, **hist}

    return loop


def fused_decbyzpg(env, cfg: DecByzPGConfig, T: int):
    """Jitted fused loop, cached per static config shape; the θ_0 carry
    buffer is donated (it aliases the final θ output — θ_prev/opt have no
    matching output to alias, so donating them would only be dead weight;
    the ``repro.analysis`` donation audit enforces this)."""
    key = ("decbyzpg", env.name, env.horizon, engine.static_key(cfg), T)
    return engine.compiled(key, lambda: jax.jit(
        build_decbyzpg_loop(env, cfg, T),
        donate_argnums=engine.donate_args(0)))


def _finalize(cfg, unravel, hist) -> dict:
    coins = np.asarray(hist["coins"])
    theta = hist["theta"]
    honest_idx = min(cfg.n_byz, cfg.K - 1)
    out = {"returns": np.asarray(hist["returns"]),
           "samples": np.cumsum(np.where(coins, cfg.N, cfg.B)),
           "diameter": np.asarray(hist["diameter"]),
           "params": unravel(theta[honest_idx]),
           "theta": theta}
    if "rejected" in hist:
        out["grad_norm"] = np.asarray(hist["grad_norm"])
        out["rejected"] = np.asarray(hist["rejected"])
        out["aggregator_confusion"] = obs.confusion_tally(
            out["rejected"], cfg.n_byz)
    return out


def run_decbyzpg(env, cfg: DecByzPGConfig, T: int):
    """Returns history of honest mean returns, per-agent sample counts, and
    the honest parameter diameter trace (Lemma 1/2 diagnostic)."""
    ks = engine.seed_keys(cfg.seed)
    unravel, _ = policy_unraveler(resolve_policy(cfg, env))
    carry = init_decbyzpg_carry(env, cfg, ks.init)
    loop = fused_decbyzpg(env, cfg, T)
    hist = jax.block_until_ready(
        loop(*carry, jax.random.split(ks.loop, T), ks.coin))
    return _finalize(cfg, unravel, hist)


def run_decbyzpg_legacy(env, cfg: DecByzPGConfig, T: int):
    """Per-step dispatch harness over the *same* step function: a Python
    T-loop, a fresh jit per call, and a host sync per iteration — the
    pre-engine execution model, kept for the scan-vs-dispatch equivalence
    test and the ``bench_engine`` baseline."""
    ks = engine.seed_keys(cfg.seed)
    unravel, _ = policy_unraveler(resolve_policy(cfg, env))
    theta, theta_prev, opt = init_decbyzpg_carry(env, cfg, ks.init)
    step = jax.jit(build_decbyzpg_step(env, cfg), static_argnums=())
    step_keys = jax.random.split(ks.loop, T)
    rets, coins, diams = [], [], []
    for t in range(T):
        # ys grows telemetry entries under cfg.telemetry; the first three
        # are always (return, coin, diameter)
        (theta, theta_prev, opt), ys = step(
            (theta, theta_prev, opt), (jnp.int32(t), step_keys[t]), ks.coin)
        rets.append(float(ys[0]))
        coins.append(bool(ys[1]))
        diams.append(float(ys[2]))
    hist = {"theta": theta, "returns": np.asarray(rets),
            "coins": np.asarray(coins), "diameter": np.asarray(diams)}
    return _finalize(cfg, unravel, hist)


register("algo", "decbyzpg")(lambda: engine.AlgoDef(
    DecByzPGConfig, build_decbyzpg_loop, init_decbyzpg_carry,
    run_decbyzpg, run_decbyzpg_legacy,
    traced_fields=("eta", "gamma", "baseline", "switch_p"),
    build_window=build_decbyzpg_window, carry_hist="theta"))
