"""Byzantine attack library (paper §6.2 + standard literature attacks).

An attack is ``fn(honest_msgs (K, d), byz_mask (K,), key) -> (K, d)`` —
rows where ``byz_mask`` is True are replaced with adversarial values, the
rest are returned untouched. The adversary is omniscient: it sees all honest
messages (AvgZero exploits this, per the paper). ``per_receiver(attack)``
lifts any attack to send independently drawn values to every receiver
(a (K, K, d) message tensor), which the agreement simulator accepts.

``RandomAction`` is environment-level (a Byzantine agent interacts with its
environment using uniformly random actions but computes its gradient
honestly); it registers with ``env_level=True`` metadata and the algorithm
drivers branch on :func:`is_env_level`.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.registry import REGISTRY, Spec, register, resolve


def _apply(byz_fn, honest, byz_mask, key):
    byz_vals = byz_fn(honest, byz_mask, key)
    return jnp.where(byz_mask[:, None], byz_vals, honest)


def none_attack(honest, byz_mask, key):
    return honest


def large_noise(honest, byz_mask, key, sigma: float = 100.0):
    """Byzantines send pure noise of large variance (paper: LargeNoise)."""
    noise = sigma * jax.random.normal(key, honest.shape, honest.dtype)
    return jnp.where(byz_mask[:, None], noise, honest)


def avg_zero(honest, byz_mask, key):
    """Colluding omniscient attack: Byzantine values are chosen so the
    *average over all K messages* is (close to) zero (paper: AvgZero)."""
    n_byz = jnp.maximum(jnp.sum(byz_mask), 1)
    honest_sum = jnp.sum(jnp.where(byz_mask[:, None], 0.0, honest), axis=0)
    byz_val = -honest_sum / n_byz
    return jnp.where(byz_mask[:, None], byz_val[None], honest)


def sign_flip(honest, byz_mask, key, scale: float = 3.0):
    """Byzantines send the negated (scaled) honest mean (IPM-style [22])."""
    n_h = jnp.maximum(jnp.sum(~byz_mask), 1)
    mu = jnp.sum(jnp.where(byz_mask[:, None], 0.0, honest), axis=0) / n_h
    return jnp.where(byz_mask[:, None], -scale * mu[None], honest)


def alie(honest, byz_mask, key, z: float = 1.5):
    """A Little Is Enough: honest mean shifted by z std-devs per coordinate
    — crafted to hide inside the honest spread."""
    n_h = jnp.maximum(jnp.sum(~byz_mask), 1)
    w = (~byz_mask).astype(honest.dtype)[:, None]
    mu = jnp.sum(w * honest, axis=0) / n_h
    var = jnp.sum(w * (honest - mu) ** 2, axis=0) / n_h
    byz_val = mu - z * jnp.sqrt(var + 1e-12)
    return jnp.where(byz_mask[:, None], byz_val[None], honest)


# -- registry factories ------------------------------------------------------

register("attack", "none")(lambda: none_attack)
register("attack", "avg_zero")(lambda: avg_zero)


# ``traced_kwargs`` marks kwargs that are pure numeric multipliers inside
# the attack body: the engine's lane batching (DESIGN.md §2) strips them
# from the static spec and feeds them to the compiled program as data, so
# e.g. a sigma sweep of large_noise compiles once instead of per-point.

@register("attack", "large_noise", traced_kwargs=("sigma",))
def _large_noise_factory(sigma: float = 100.0):
    return functools.partial(large_noise, sigma=sigma)


@register("attack", "sign_flip", traced_kwargs=("scale",))
def _sign_flip_factory(scale: float = 3.0):
    return functools.partial(sign_flip, scale=scale)


@register("attack", "alie", traced_kwargs=("z",))
def _alie_factory(z: float = 1.5):
    return functools.partial(alie, z=z)


# env-level: the message path is honest, drivers zero the agent's logits
register("attack", "random_action", env_level=True)(lambda: none_attack)


def is_env_level(spec) -> bool:
    """True when the attack corrupts environment interaction rather than
    messages (registry metadata; paper: RandomAction)."""
    return bool(REGISTRY.meta("attack", spec).get("env_level", False))


def get_attack(name, **kw) -> Callable:
    """Resolve an attack spec (name, spec string, or Spec); extra ``kw``
    merge into the spec's kwargs (explicit spec kwargs win)."""
    spec = Spec.of(name)
    if kw:
        spec = spec.with_kwargs(**kw)
    return resolve("attack", spec)


def per_receiver(attack: Callable, K: int) -> Callable:
    """Lift an attack to send independent values to each receiver."""

    def fn(honest, byz_mask, key):
        keys = jax.random.split(key, K)
        return jax.vmap(lambda k: attack(honest, byz_mask, k))(keys)

    return fn
