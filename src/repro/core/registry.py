"""Unified component-spec registry (DESIGN.md §4).

Every pluggable component — aggregators, attacks, agreement methods,
gradient estimators, optimizers, environments, algorithms — is registered
under a namespace and addressed by a :class:`Spec`: a frozen, hashable
``(name, sorted kwargs)`` value that parses from strings and round-trips
to a canonical string::

    Spec.of("krum")                          -> krum
    Spec.of("krum(m=3)")                     -> krum(m=3)
    Spec.of("bucketing(s=2, inner=rfa(n_iter=64))").canonical()
        -> "bucketing(inner=rfa(n_iter=64), s=2)"

Because a Spec is frozen and hashable, config dataclasses can hold Specs
directly: ``dataclasses.replace``/``engine.static_key`` hashing and the
compiled-loop cache work unchanged, and two configs built from the string
and Spec forms of the same component hash equal.

Registration happens in the module that owns the component::

    @register("aggregator", "krum")
    def _krum(K, n_byz, m=1, alpha_max=0.25): ...

Factories are plain callables; :func:`resolve` calls them with the spec's
kwargs plus whatever *context* kwargs (``K=...``, ``n_byz=...``,
``lr=...``) their signature accepts — context the factory doesn't name is
silently dropped, so one ``resolve`` call site serves factories with
different needs. Spec kwargs win over context on collision (an explicit
``trimmed_mean(n_byz=2)`` overrides the config's n_byz). Unknown names
raise ``KeyError`` listing the namespace's registered components; kwargs
the factory doesn't accept raise ``TypeError`` before the factory runs.

Namespaces resolve lazily: the first lookup in a namespace imports the
modules listed in ``_PROVIDERS`` so components self-register without this
module importing (and circularly depending on) any of them.
"""
from __future__ import annotations

import ast
import importlib
import inspect
from typing import Any, Callable, Dict, Optional, Tuple


class SpecError(ValueError):
    """A component spec string failed to parse."""


class Spec:
    """Frozen, hashable component spec: a name plus keyword arguments.

    ``kwargs`` is stored as a key-sorted tuple of ``(key, value)`` pairs so
    equal specs hash equal regardless of argument order. Values may be
    numbers, bools, None, strings, tuples, or nested Specs.
    """

    __slots__ = ("name", "kwargs")

    def __init__(self, name: str, **kwargs):
        if not name.isidentifier():
            raise SpecError(f"component name must be an identifier, "
                            f"got {name!r}")
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "kwargs", tuple(
            sorted((k, _norm_value(v)) for k, v in kwargs.items())))

    def __setattr__(self, *_):
        raise AttributeError("Spec is immutable")

    # -- construction -------------------------------------------------------

    @classmethod
    def of(cls, value) -> "Spec":
        """Coerce a Spec | string into a Spec (idempotent)."""
        if isinstance(value, Spec):
            return value
        if isinstance(value, str):
            return cls.parse(value)
        raise SpecError(f"cannot make a Spec from {type(value).__name__}: "
                        f"{value!r}")

    @classmethod
    def parse(cls, s: str) -> "Spec":
        """Parse ``"name"`` or ``"name(k=v, ...)"``; nested calls become
        nested Specs."""
        try:
            node = ast.parse(s.strip(), mode="eval").body
        except SyntaxError as e:
            raise SpecError(f"invalid spec string {s!r}: {e.msg}") from None
        return _spec_from_node(node, s)

    def with_kwargs(self, **kwargs) -> "Spec":
        """New Spec with ``kwargs`` merged in (existing keys kept)."""
        merged = dict(kwargs)
        merged.update(dict(self.kwargs))
        return Spec(self.name, **merged)

    # -- canonical form -----------------------------------------------------

    def canonical(self) -> str:
        if not self.kwargs:
            return self.name
        inner = ", ".join(f"{k}={_fmt_value(v)}" for k, v in self.kwargs)
        return f"{self.name}({inner})"

    def __str__(self) -> str:
        return self.canonical()

    def __repr__(self) -> str:
        return f"Spec({self.canonical()!r})"

    # -- value semantics ----------------------------------------------------

    def __eq__(self, other) -> bool:
        if isinstance(other, Spec):
            return (self.name, self.kwargs) == (other.name, other.kwargs)
        return NotImplemented

    def __hash__(self) -> int:
        return hash((Spec, self.name, self.kwargs))

    def __reduce__(self):
        return (Spec.parse, (self.canonical(),))


def _norm_value(v):
    if isinstance(v, float) and not (v == v and abs(v) != float("inf")):
        # repr(inf/nan) does not parse back (ast reads "inf" as a Name), so
        # the canonical form would not round-trip — reject at construction
        raise SpecError(f"non-finite spec kwarg value: {v!r}")
    if isinstance(v, (Spec, bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return tuple(_norm_value(x) for x in v)
    raise SpecError(f"unsupported spec kwarg value: {v!r}")


def _fmt_value(v) -> str:
    if isinstance(v, Spec):
        return v.canonical()
    if isinstance(v, tuple):
        inner = ", ".join(_fmt_value(x) for x in v)
        return f"({inner},)" if len(v) == 1 else f"({inner})"
    return repr(v)


def _spec_from_node(node, src: str) -> Spec:
    if isinstance(node, ast.Name):
        return Spec(node.id)
    if isinstance(node, ast.Call):
        if not isinstance(node.func, ast.Name):
            raise SpecError(f"invalid spec string {src!r}: component name "
                            f"must be a plain identifier")
        if node.args:
            raise SpecError(f"invalid spec string {src!r}: only keyword "
                            f"arguments are allowed")
        kwargs = {}
        for kw in node.keywords:
            if kw.arg is None:
                raise SpecError(f"invalid spec string {src!r}: ** is not "
                                f"allowed")
            kwargs[kw.arg] = _value_from_node(kw.value, src)
        return Spec(node.func.id, **kwargs)
    raise SpecError(f"invalid spec string {src!r}")


def _value_from_node(node, src: str):
    if isinstance(node, (ast.Name, ast.Call)):
        return _spec_from_node(node, src)
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (bool, int, float, str)) \
                or node.value is None:
            return node.value
        raise SpecError(f"invalid spec string {src!r}: unsupported constant "
                        f"{node.value!r}")
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(_value_from_node(e, src) for e in node.elts)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub) \
            and isinstance(node.operand, ast.Constant) \
            and isinstance(node.operand.value, (int, float)):
        return -node.operand.value
    raise SpecError(f"invalid spec string {src!r}: unsupported value "
                    f"expression")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# namespace -> modules whose import registers that namespace's built-ins
_PROVIDERS: Dict[str, Tuple[str, ...]] = {  # analysis: not-a-spec
    "aggregator": ("repro.core.aggregators",),
    "attack": ("repro.core.attacks",),
    "agreement": ("repro.core.agreement",),
    "estimator": ("repro.rl.gradient",),
    "optimizer": ("repro.optim.optimizers",),
    "env": ("repro.rl.envs",),
    "topology": ("repro.topology.graphs",),
    "algo": ("repro.core.decbyzpg", "repro.core.byzpg"),
    "policy": ("repro.rl.policy", "repro.rl.transformer_policy"),
    "fed_aggregator": ("repro.distributed.aggregation",),
    "fed_attack": ("repro.distributed.aggregation",),
    "kernel": ("repro.kernels.pairwise_dist.ops",
               "repro.kernels.trimmed_mean.ops",
               "repro.kernels.gossip_reduce.ops",
               "repro.kernels.rfa.ops",
               "repro.kernels.krum_score.ops",
               "repro.kernels.flash_attention.ops"),
}


class Registry:
    """Namespaced component registry mapping ``(namespace, name)`` to a
    factory callable plus metadata."""

    def __init__(self):
        self._factories: Dict[Tuple[str, str], Callable] = {}
        self._meta: Dict[Tuple[str, str], dict] = {}
        self._loaded: set = set()

    def register(self, namespace: str, name: Optional[str] = None, **meta):
        """Decorator: ``@register("aggregator", "krum", **meta)``. The
        factory's ``__name__`` (minus leading underscores) is used when
        ``name`` is omitted."""

        def deco(factory):
            key = (namespace, name or factory.__name__.lstrip("_"))
            self._factories[key] = factory
            self._meta[key] = meta
            return factory

        return deco

    def _ensure_loaded(self, namespace: str) -> None:
        if namespace in self._loaded:
            return
        for mod in _PROVIDERS.get(namespace, ()):
            importlib.import_module(mod)
        # only after every provider imported cleanly — a failed import must
        # surface again on the next lookup, not decay into "unknown name"
        self._loaded.add(namespace)

    def names(self, namespace: str) -> Tuple[str, ...]:
        self._ensure_loaded(namespace)
        return tuple(sorted(n for ns, n in self._factories
                            if ns == namespace))

    def meta(self, namespace: str, spec) -> dict:
        name = Spec.of(spec).name
        self._factory(namespace, name)          # raises on unknown
        return self._meta[(namespace, name)]

    def split_traced(self, namespace: str, spec):
        """Split ``spec`` into its static program shape and its traced
        scalar operands (DESIGN.md §2, lane batching).

        A factory registered with ``traced_kwargs=("sigma", ...)`` marks
        those kwargs as *batchable*: pure numeric multipliers that can be
        fed to the compiled program as data instead of being baked into
        its shape. Returns ``(static_spec, traced)`` where ``static_spec``
        has every traced kwarg stripped and ``traced`` maps each traced
        kwarg name to its float value — the spec's explicit value when
        given, else the factory's default — so every spec of the same
        component normalizes to the same static signature and the same
        traced-name set regardless of which kwargs were spelled out.
        Non-numeric (or bool) values for a traced-marked kwarg stay
        static.
        """
        spec = Spec.of(spec)
        marked = self.meta(namespace, spec).get("traced_kwargs", ())
        if not marked:
            return spec, {}
        factory = self._factory(namespace, spec.name)
        defaults = {n: p.default
                    for n, p in inspect.signature(factory).parameters.items()
                    if p.default is not inspect.Parameter.empty}
        kwargs = dict(spec.kwargs)
        traced = {}
        for name in marked:
            value = kwargs.get(name, defaults.get(name))
            if isinstance(value, (int, float)) \
                    and not isinstance(value, bool):
                traced[name] = float(value)
                kwargs.pop(name, None)
        return Spec(spec.name, **kwargs), traced

    #: factory parameters exempt from the traced/static audit: federation
    #: shape (K, n_byz), nested component specs, and backend toggles
    AUDIT_EXEMPT = ("K", "n_byz", "inner", "sharded")

    def unclassified_kwargs(self, namespace: str) -> Dict[str, tuple]:
        """Traced-eligibility audit (DESIGN.md §12): every factory kwarg
        with a numeric default must be deliberately classified as either
        ``traced_kwargs`` (lane-batchable data — sweeping it keeps one
        compiled program) or ``static_kwargs`` (program shape: loop trip
        counts, top-k/reshape sizes, host-side bucket math).  Returns
        ``{component: (kwarg, ...)}`` for any name in neither set — the
        audit test keeps this empty so new scalars can't silently narrow
        sweep lane groups."""
        self._ensure_loaded(namespace)
        out: Dict[str, tuple] = {}
        for (ns, name), factory in sorted(self._factories.items()):
            if ns != namespace:
                continue
            meta = self._meta[(ns, name)]
            classified = (set(meta.get("traced_kwargs", ()))
                          | set(meta.get("static_kwargs", ())))
            missing = tuple(
                n for n, p in inspect.signature(factory)
                .parameters.items()
                if n not in self.AUDIT_EXEMPT and n not in classified
                and isinstance(p.default, (int, float))
                and not isinstance(p.default, bool))
            if missing:
                out[name] = missing
        return out

    def _factory(self, namespace: str, name: str) -> Callable:
        self._ensure_loaded(namespace)
        try:
            return self._factories[(namespace, name)]
        except KeyError:
            known = ", ".join(self.names(namespace)) or "<none>"
            raise KeyError(f"unknown {namespace} component {name!r}; "
                           f"registered: {known}") from None

    def resolve(self, namespace: str, spec, **context) -> Any:
        """Build the component named by ``spec`` (Spec or string).

        ``context`` carries call-site structure (K, n_byz, lr, ...); only
        the entries the factory's signature names are passed through, and
        explicit spec kwargs take precedence over context.
        """
        spec = Spec.of(spec)
        factory = self._factory(namespace, spec.name)
        params = inspect.signature(factory).parameters
        var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                     for p in params.values())
        accepted = {n for n, p in params.items()
                    if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                                  inspect.Parameter.KEYWORD_ONLY)}
        kwargs = dict(spec.kwargs)
        if not var_kw:
            bad = set(kwargs) - accepted
            if bad:
                raise TypeError(
                    f"{namespace}/{spec.name} got unexpected kwarg(s) "
                    f"{sorted(bad)}; accepted: {sorted(accepted)}")
        for k, v in context.items():
            if k in accepted or var_kw:
                kwargs.setdefault(k, v)
        return factory(**kwargs)


REGISTRY = Registry()
register = REGISTRY.register
resolve = REGISTRY.resolve
split_traced = REGISTRY.split_traced


def normalize_spec_fields(cfg, fields) -> None:
    """Shared ``__post_init__`` body for frozen config dataclasses:
    coerce each named str|Spec field to a Spec, so the string and Spec
    forms of a config compare and hash equal."""
    for f in fields:
        object.__setattr__(cfg, f, Spec.of(getattr(cfg, f)))
