"""Minimal dependency-free checkpointing: pytree -> npz (+ tree structure
by key-path), with exact-structure restore."""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(
            p, "idx", p)))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(tree, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(template, path: str):
    """Restore into the structure of ``template`` (shape/dtype checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "name", getattr(
            q, "idx", q)))) for q in p)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves)
