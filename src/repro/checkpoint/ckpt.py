"""Minimal dependency-free checkpointing: pytree -> npz (+ tree structure
by key-path), with validated-structure restore.

Writes are atomic (temp sibling + ``os.replace``) so a crash mid-write
never leaves a torn file — the sweep service (DESIGN.md §12) resumes
from whatever its manifest last committed, and a half-written carry
would otherwise poison the resume.
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "name", getattr(
        p, "idx", p)))) for p in path)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_key(path): np.asarray(leaf) for path, leaf in flat}


def _npz(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save(tree, path: str) -> None:
    path = _npz(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + f".tmp-{os.getpid()}.npz"
    try:
        np.savez(tmp, **_flatten(tree))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(template, path: str, cast_dtypes: bool = False):
    """Restore into the structure of ``template`` (real arrays or
    ``ShapeDtypeStruct`` leaves, e.g. from ``jax.eval_shape``).

    Structure, shape, and dtype are validated *by key path* before any
    unflattening, so a mismatched checkpoint raises one ``ValueError``
    naming every offending field (keys missing from the file, keys the
    template lacks, per-leaf shape/dtype deltas) instead of failing deep
    inside ``tree_unflatten``.  ``cast_dtypes=True`` allows
    dtype-changing loads (e.g. an f32 file into a bf16 template) — still
    shape-checked."""
    data = np.load(_npz(path))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = [_key(p) for p, _ in flat]
    in_file = set(data.files)
    problems = [f"{k}: in template but missing from file"
                for k in keys if k not in in_file]
    problems += [f"{k}: in file but not in template"
                 for k in sorted(in_file - set(keys))]
    for k, (_, leaf) in zip(keys, flat):
        if k not in in_file:
            continue
        arr = data[k]
        if arr.shape != tuple(leaf.shape):
            problems.append(f"{k}: shape {arr.shape} != "
                            f"{tuple(leaf.shape)}")
        elif not cast_dtypes and arr.dtype != np.dtype(leaf.dtype):
            problems.append(f"{k}: dtype {arr.dtype} != "
                            f"{np.dtype(leaf.dtype)} "
                            f"(cast_dtypes=True to allow)")
    if problems:
        raise ValueError(
            f"checkpoint {path!r} does not match the restore template "
            f"({len(problems)} field(s)): " + "; ".join(problems))
    leaves = [jnp.asarray(data[k], dtype=leaf.dtype)
              for k, (_, leaf) in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
