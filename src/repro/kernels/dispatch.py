"""Unified kernel-backend dispatch (DESIGN.md §6).

Every compute kernel in ``repro/kernels/`` is a :class:`Kernel`: one name,
three backends —

* ``"pallas"``           — the compiled Pallas TPU kernel,
* ``"pallas-interpret"`` — the *same* kernel body run by the Pallas
  interpreter (any backend; this is how CPU CI exercises the real kernel
  code instead of only the oracle),
* ``"jnp"``              — the pure-jnp oracle from the kernel's ``ref.py``.

Backend selection, most specific wins:

1. per-call ``backend=`` keyword,
2. the process-global override (:func:`set_backend` / :func:`use_backend`),
3. the ``REPRO_KERNEL_BACKEND`` environment variable,
4. auto: ``"pallas"`` on TPU, ``"jnp"`` elsewhere — with a per-kernel
   size-threshold fallback: below ``auto_jnp_below`` operand elements
   (declared at registration, calibrated from BENCH_kernels.json) the
   launch/interpret overhead dominates and auto picks ``"jnp"`` even on
   TPU. Explicit overrides (1-3) are never second-guessed.

The d-tiled kernels accept a ``block_d=`` slab width (the VMEM tile along
the parameter dimension). It is a Pallas tiling knob only, so the
dispatcher strips it before calling the ``jnp`` oracle — parity across
backends holds by construction for every ``block_d``.

Selection is a trace-time (Python-level) decision, so a jitted caller bakes
the chosen backend into the compiled program; re-jit (a fresh closure) to
switch backends.

Kernels register here via :func:`register_kernel` and are *also* exposed as
the ``kernel`` registry namespace, so ``resolve("kernel", "trimmed_mean")``
returns the same dispatching callable as :func:`get_kernel` and
``REGISTRY.names("kernel")`` lists the suite.
"""
from __future__ import annotations

import collections
import contextlib
import os
from typing import Callable, Dict, Optional

import jax

BACKENDS = ("pallas", "pallas-interpret", "jnp")

#: process-global backend override; ``None`` defers to env var / auto.
_GLOBAL_BACKEND: Optional[str] = None

_KERNELS: Dict[str, "Kernel"] = {}

#: backend-selection tally keyed ``(kernel_name, backend, reason)`` with
#: reason in {"call", "global", "env", "auto", "auto_jnp_below"} — one
#: increment per trace-time dispatch decision, so a silent
#: ``auto_jnp_below`` fallback shows up here (and in the obs manifest)
#: instead of only as a 2x bench miss. Always on: selection happens at
#: trace time, never inside a compiled program.
_DISPATCH_COUNTS: collections.Counter = collections.Counter()


def dispatch_counts() -> Dict[tuple, int]:
    """Snapshot of the backend-selection tally (see above)."""
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    _DISPATCH_COUNTS.clear()


def _check_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    return backend


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_backend() -> str:
    """The backend used when nothing overrides: env var, else auto."""
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return _check_backend(env)
    return "pallas" if on_tpu() else "jnp"


def current_backend() -> str:
    """The backend a kernel call would use right now (without a per-call
    override)."""
    return _GLOBAL_BACKEND or default_backend()


def set_backend(backend: Optional[str]) -> None:
    """Set (or with ``None`` clear) the process-global backend override."""
    global _GLOBAL_BACKEND
    _GLOBAL_BACKEND = _check_backend(backend) if backend else None


@contextlib.contextmanager
def use_backend(backend: Optional[str]):
    """Scoped :func:`set_backend`; applies to traces entered in the scope."""
    prev = _GLOBAL_BACKEND
    set_backend(backend)
    try:
        yield
    finally:
        set_backend(prev)


class Kernel:
    """A named kernel dispatching to one of its backend implementations.

    ``pallas`` and ``pallas-interpret`` share one implementation taking an
    ``interpret`` keyword; ``jnp`` is the oracle. All other arguments pass
    through unchanged — except the Pallas tiling knob ``block_d``, which
    is dropped for the oracle — so a Kernel is call-compatible with its
    oracle plus optional ``backend=`` / ``block_d=`` keywords.

    ``auto_jnp_below`` (element count of the first operand) is the
    auto-mode fallback threshold: when no per-call/global/env override is
    active and auto would pick Pallas, operands smaller than this run the
    oracle instead (kernel launch overhead dominates tiny stacks).
    """

    __slots__ = ("name", "_jnp", "_pallas", "auto_jnp_below")

    def __init__(self, name: str, jnp_impl: Callable, pallas_impl: Callable,
                 auto_jnp_below: int = 0):
        self.name = name
        self._jnp = jnp_impl
        self._pallas = pallas_impl
        self.auto_jnp_below = auto_jnp_below

    def impl(self, backend: Optional[str] = None) -> Callable:
        b = _check_backend(backend) if backend else current_backend()
        if b == "jnp":
            return lambda *a, **kw: self._jnp(
                *a, **{k: v for k, v in kw.items() if k != "block_d"})
        if b == "pallas-interpret":
            return lambda *a, **kw: self._pallas(*a, interpret=True, **kw)
        return lambda *a, **kw: self._pallas(*a, interpret=False, **kw)

    def resolve_backend(self, *args, backend: Optional[str] = None) -> str:
        """The backend this call would dispatch to (trace-time decision).

        Explicit choices (per-call, global, env var) pass through
        untouched; only the pure-auto path applies the size fallback,
        reading the first operand's static element count.
        """
        if backend:
            return self._tally(_check_backend(backend), "call")
        if _GLOBAL_BACKEND:
            return self._tally(_GLOBAL_BACKEND, "global")
        if os.environ.get("REPRO_KERNEL_BACKEND"):
            return self._tally(default_backend(), "env")
        b = default_backend()
        if b == "pallas" and self.auto_jnp_below and args:
            size = getattr(args[0], "size", None)
            if size is not None and size < self.auto_jnp_below:
                return self._tally("jnp", "auto_jnp_below")
        return self._tally(b, "auto")

    def _tally(self, backend: str, reason: str) -> str:
        _DISPATCH_COUNTS[(self.name, backend, reason)] += 1
        return backend

    def __call__(self, *args, backend: Optional[str] = None, **kwargs):
        return self.impl(self.resolve_backend(*args, backend=backend)
                         )(*args, **kwargs)

    def __repr__(self) -> str:
        return f"Kernel({self.name!r})"


def register_kernel(name: str, *, jnp_impl: Callable, pallas_impl: Callable,
                    auto_jnp_below: int = 0, **meta) -> Kernel:
    """Create a :class:`Kernel` and file it under the ``kernel`` registry
    namespace (metadata, including ``auto_jnp_below``, lands in
    ``REGISTRY.meta("kernel", name)``)."""
    from repro.core.registry import REGISTRY
    k = Kernel(name, jnp_impl, pallas_impl, auto_jnp_below=auto_jnp_below)
    _KERNELS[name] = k
    REGISTRY.register("kernel", name, auto_jnp_below=auto_jnp_below,
                      **meta)(lambda _k=k: _k)
    return k


def get_kernel(name: str) -> Kernel:
    """Look up a registered kernel, importing providers on first use."""
    if name not in _KERNELS:
        from repro.core.registry import resolve
        # imports the kernel providers, raises KeyError with the
        # registered names on a miss, and returns the Kernel itself
        return resolve("kernel", name)
    return _KERNELS[name]


def tpu_compiler_params(**kwargs):
    """Version-compat shim: jax renamed ``pltpu.TPUCompilerParams`` to
    ``pltpu.CompilerParams`` (and back again across releases); pick
    whichever this jax provides."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
