"""Jit'd public entry point for flash attention.

Accepts model-layout tensors q: (B, Sq, H, hd), k/v: (B, Sk, Hkv, hd).
"""
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fold(x):
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _unfold(x, B):
    BH, S, hd = x.shape
    return x.reshape(B, BH // B, S, hd).transpose(0, 2, 1, 3)


def flash_attention(q, k, v, window=None, use_pallas=None, **kw):
    if use_pallas is None:
        use_pallas = _on_tpu()
    B, Sq, H, hd = q.shape
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    if use_pallas:
        out = flash_attention_pallas(qf, kf, vf, n_q_heads=H, window=window,
                                     interpret=not _on_tpu(), **kw)
    else:
        out = ref.attention(qf, kf, vf, n_q_heads=H, window=window)
    return _unfold(out, B)
