"""Dispatched entry point for flash attention.

Accepts model-layout tensors q: (B, Sq, H, hd), k/v: (B, Sk, Hkv, hd).
``use_pallas`` is kept for backward compatibility and maps onto the
dispatch backends (True -> pallas, interpreted off-TPU; False -> jnp).
"""
from repro.kernels.dispatch import on_tpu, register_kernel
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)


def _fold(x):
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _unfold(x, B):
    BH, S, hd = x.shape
    return x.reshape(B, BH // B, S, hd).transpose(0, 2, 1, 3)


def _jnp_impl(q, k, v, n_q_heads, window=None, **_pallas_only):
    # block_q/block_k (and other Pallas tuning kwargs) are meaningless to
    # the oracle; accept and drop them so a caller can flip backends
    # without changing its call
    return ref.attention(q, k, v, n_q_heads=n_q_heads, window=window)


_kernel = register_kernel(
    "flash_attention", jnp_impl=_jnp_impl, pallas_impl=flash_attention_pallas)


def flash_attention(q, k, v, window=None, use_pallas=None, backend=None,
                    **kw):
    if backend is None and use_pallas is not None:
        backend = ("pallas" if on_tpu() else "pallas-interpret") \
            if use_pallas else "jnp"
    B, Sq, H, hd = q.shape
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    out = _kernel(qf, kf, vf, n_q_heads=H, window=window, backend=backend,
                  **kw)
    return _unfold(out, B)
