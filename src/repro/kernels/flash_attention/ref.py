"""Pure-jnp oracle for flash attention (masked softmax, GQA)."""
import jax
import jax.numpy as jnp


def attention(q, k, v, n_q_heads, window=None):
    """q: (B*H, Sq, hd); k, v: (B*Hkv, Sk, hd) -> (B*H, Sq, hd). Causal."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    H = n_q_heads
    B = BH // H
    Hkv = BHkv // B
    G = H // Hkv
    qf = q.reshape(B, Hkv, G, Sq, hd).astype(jnp.float32)
    kf = k.reshape(B, Hkv, Sk, hd).astype(jnp.float32)
    vf = v.reshape(B, Hkv, Sk, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return o.reshape(BH, Sq, hd).astype(q.dtype)
