"""Pallas TPU kernel: online-softmax (flash) causal attention with GQA and
optional sliding window.

Tiling: grid = (B*H, Sq/block_q, Sk/block_k); q/k/v blocks live in VMEM,
running max/denominator/accumulator in VMEM scratch. GQA is handled in the
BlockSpec index map (query head h reads kv head h // group), so grouped KV
is never materialized. The kv axis is the innermost ("arbitrary") grid
dimension; out-of-window blocks are masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(scale, window, block_q, block_k, n_k,
                  q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * block_q + \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_q_heads", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_pallas(q, k, v, n_q_heads: int, window=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B*H, Sq, hd); k, v: (B*Hkv, Sk, hd) -> (B*H, Sq, hd).

    Causal attention (positions are absolute indices 0..S-1 on both sides).
    """
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    H = n_q_heads
    Hkv = BHkv // (BH // H)
    G = H // Hkv
    scale = hd ** -0.5

    hp = max(128, -(-hd // 128) * 128)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, hp - hd)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, hp - hd)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, hp - hd)))
    n_k = Skp // bk

    def kv_head(bh):
        return (bh // H) * Hkv + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale, window, bq, bk, n_k),
        grid=(BH, Sqp // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hp), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hp), lambda bh, i, j: (kv_head(bh), j, 0)),
            pl.BlockSpec((1, bk, hp), lambda bh, i, j: (kv_head(bh), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hp), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, hp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hp), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qp, kp, vp)
    return out[:, :Sq, :hd]
