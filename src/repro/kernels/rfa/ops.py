"""Dispatched entry point for RFA (smoothed-Weiszfeld geometric median)."""
from repro.kernels.dispatch import register_kernel
from repro.kernels.rfa import ref
from repro.kernels.rfa.rfa import rfa_pallas

rfa = register_kernel("rfa", jnp_impl=ref.rfa, pallas_impl=rfa_pallas)
