"""Dispatched entry point for RFA (smoothed-Weiszfeld geometric median)."""
from repro.kernels.dispatch import register_kernel
from repro.kernels.rfa import ref
from repro.kernels.rfa.rfa import rfa_pallas

# launch-overhead cutoff: under ~2k stack elements the oracle wins
# (BENCH_kernels.json smallest point); auto dispatches jnp below it
rfa = register_kernel("rfa", jnp_impl=ref.rfa, pallas_impl=rfa_pallas,
                      auto_jnp_below=2048)
