"""Pallas TPU kernel: RFA geometric median (smoothed Weiszfeld).

At model scale (d ≫ K) the naive iteration reads the full (K, d) stack
``n_iter`` times. This kernel exploits the same decomposition as
DESIGN.md §3: every Weiszfeld iterate stays in the affine hull of the
inputs, so with the Gram matrix ``G = X Xᵀ`` (one d-tiled MXU pass, the
existing ``pairwise_dist`` kernel) the iteration runs entirely in
*weight space*::

    z_t = w_tᵀ X,   ‖x_i − z_t‖² = G_ii − 2 (G w_t)_i + w_tᵀ G w_t

The full Weiszfeld loop (pairwise norm + reweighted sum per step,
``lax.fori_loop`` over ``n_iter``) is fused into one VMEM-resident kernel
over the (K, K) Gram matrix; a final d-tiled pass materializes
``z = wᵀ X``. Total HBM traffic: two passes over X instead of
``2·n_iter``.

Numerics: distances come from the Gram identity rather than a direct
subtraction, so tiny distances lose precision to cancellation — the
smoothing floor ``nu`` (the same one the oracle uses) bounds the effect.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist.pairwise_dist import gram


def _weiszfeld_kernel(n_iter, K, g_ref, nu_ref, w_ref):
    G = g_ref[...]                                       # (Kp, Kp) f32
    nu = nu_ref[0, 0]                # traced operand: lane-batchable sweeps
    Kp = G.shape[0]
    valid = jax.lax.broadcasted_iota(jnp.int32, (Kp, 1), 0) < K
    eye = (jax.lax.broadcasted_iota(jnp.int32, (Kp, Kp), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (Kp, Kp), 1))
    diag = jnp.sum(jnp.where(eye, G, 0.0), axis=1, keepdims=True)

    def body(_, w):
        Gw = jax.lax.dot_general(G, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        wGw = jnp.sum(w * Gw)
        d2 = jnp.maximum(diag - 2.0 * Gw + wGw, 0.0)
        iw = jnp.where(valid, 1.0 / jnp.sqrt(d2 + nu), 0.0)
        return iw / jnp.sum(iw)

    w0 = jnp.where(valid, 1.0 / K, 0.0)
    w = jax.lax.fori_loop(0, n_iter, body, w0)
    w_ref[...] = jnp.broadcast_to(w, w_ref.shape)


def _wsum_kernel(x_ref, w_ref, o_ref):
    w = w_ref[:, 0:1]                                    # (Kp, 1)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(w, x, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_iter", "block_d",
                                             "interpret"))
def rfa_pallas(x: jnp.ndarray, n_iter: int = 32, nu=1e-6,
               block_d: int = 512, interpret: bool = True) -> jnp.ndarray:
    """x: (K, d) -> (d,) smoothed geometric median (Gram-space Weiszfeld).

    ``nu`` is a *traced* operand (scalar or 0-d array), not a static
    argument: an ``rfa(nu=...)`` lane sweep shares one compiled program.
    """
    K, d = x.shape
    Kp = -(-K // 8) * 8
    G = jnp.pad(gram(x, block_d=block_d, interpret=interpret),
                ((0, Kp - K), (0, Kp - K)))
    nu_arr = jnp.broadcast_to(jnp.asarray(nu, jnp.float32), (1, 1))
    w = pl.pallas_call(
        functools.partial(_weiszfeld_kernel, n_iter, K),
        in_specs=[pl.BlockSpec((Kp, Kp), lambda: (0, 0)),
                  pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((Kp, 128), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, 128), jnp.float32),
        interpret=interpret,
    )(G, nu_arr)
    dp = -(-d // block_d) * block_d
    xp = jnp.pad(x, ((0, Kp - K), (0, dp - d)))
    z = pl.pallas_call(
        _wsum_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((Kp, block_d), lambda i: (0, i)),
                  pl.BlockSpec((Kp, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(xp, w)
    return z[0, :d]
