"""Pure-jnp oracle for RFA: geometric median via smoothed Weiszfeld [36].

This is the historical ``aggregators.rfa`` body verbatim (minus the unused
key argument) — the aggregator now routes here through the dispatcher, so
the jnp backend is bit-identical to the pre-kernel behavior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rfa(x: jnp.ndarray, n_iter: int = 32, nu: float = 1e-6) -> jnp.ndarray:
    """x: (K, d) -> (d,) smoothed geometric median."""
    z = jnp.mean(x, axis=0)

    def body(z, _):
        dist = jnp.sqrt(jnp.sum((x - z) ** 2, axis=1) + nu)
        w = 1.0 / dist
        return jnp.sum(w[:, None] * x, axis=0) / jnp.sum(w), None

    z, _ = jax.lax.scan(body, z, None, length=n_iter)
    return z
