"""Dispatched entry point for Krum neighbor scoring."""
from repro.kernels.dispatch import register_kernel
from repro.kernels.krum_score import ref
from repro.kernels.krum_score.krum_score import krum_scores_pallas

# launch-overhead cutoff: under ~2k stack elements the oracle wins
# (BENCH_kernels.json smallest point); auto dispatches jnp below it
krum_scores = register_kernel(
    "krum_score", jnp_impl=ref.krum_scores, pallas_impl=krum_scores_pallas,
    auto_jnp_below=2048)
