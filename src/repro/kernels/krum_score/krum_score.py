"""Pallas TPU kernel: Krum neighbor scoring layered on the Gram kernel.

The expensive part of Krum at model scale is the (K, K) distance matrix —
that is the existing d-tiled ``pairwise_dist`` Gram kernel. Scoring is
then a (K, K)-local problem: for each row, sum the ``n_near`` smallest
off-self distances. Instead of a sort (unavailable on the VPU) the kernel
ranks each row with the same O(K²) comparison network as the trimmed-mean
kernel (ties broken by column index, pad columns ranked last) and sums
the entries with rank in [1, n_near] — rank 0 is the self-distance. The
whole pipeline (Gram pass + scoring) stays on-device, so Krum/MDA
neighbor selection never ships a (K, d) gather to the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist.pairwise_dist import gram


def _score_kernel(n_near, K, d2_ref, o_ref):
    d2 = d2_ref[...]                                     # (Kp, Kp) f32
    Kp = d2.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (Kp, Kp), 1)
    valid = col < K
    big = jnp.float32(3.4e38)
    xv = jnp.where(valid, d2, big)                       # pad cols rank last
    a_idx = jax.lax.broadcasted_iota(jnp.int32, (Kp, Kp, Kp), 1)
    b_idx = jax.lax.broadcasted_iota(jnp.int32, (Kp, Kp, Kp), 2)
    # rank[i, b] = #{a : row i orders a before b}, ties by column index
    less = (xv[:, :, None] < xv[:, None, :]) | (
        (xv[:, :, None] == xv[:, None, :]) & (a_idx < b_idx))
    rank = jnp.sum(less.astype(jnp.int32), axis=1)       # (Kp, Kp)
    keep = (rank >= 1) & (rank < n_near + 1) & valid     # rank 0 = self
    scores = jnp.sum(jnp.where(keep, d2, 0.0), axis=1, keepdims=True)
    o_ref[...] = jnp.broadcast_to(scores, o_ref.shape)


@functools.partial(jax.jit, static_argnames=("n_near", "block_d",
                                             "interpret"))
def krum_scores_pallas(x: jnp.ndarray, n_near: int, block_d: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """x: (K, d) -> (K,) Krum scores via the Gram kernel + rank network."""
    K, d = x.shape
    Kp = -(-K // 8) * 8
    g = gram(x, block_d=block_d, interpret=interpret)
    sq = jnp.diag(g)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)
    d2p = jnp.pad(d2, ((0, Kp - K), (0, Kp - K)))
    out = pl.pallas_call(
        functools.partial(_score_kernel, n_near, K),
        in_specs=[pl.BlockSpec((Kp, Kp), lambda: (0, 0))],
        out_specs=pl.BlockSpec((Kp, 128), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, 128), jnp.float32),
        interpret=interpret,
    )(d2p)
    return out[:K, 0]
