"""Pure-jnp oracle for Krum scoring [34].

``score_i = Σ_{j ∈ closest n_near, j ≠ i} ‖x_j − x_i‖²`` — the historical
``aggregators.krum`` scoring verbatim (sort each distance row, skip the
self entry at rank 0, sum the next ``n_near``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise_dist import ref as pd_ref


def scores_from_d2(d2: jnp.ndarray, n_near: int) -> jnp.ndarray:
    """d2: (K, K) squared distances -> (K,) Krum scores."""
    near = jnp.sort(d2, axis=1)[:, 1:n_near + 1]         # skip self (0)
    return jnp.sum(near, axis=1)


def krum_scores(x: jnp.ndarray, n_near: int) -> jnp.ndarray:
    """x: (K, d) -> (K,) Krum scores over the full input set."""
    return scores_from_d2(pd_ref.pairwise_sq_dists(x), n_near)
