"""Pallas TPU kernel: masked-neighbor gossip reduce for Avg-Agree rounds.

Two entry points over the padded neighbor layout of DESIGN.md §5:

* :func:`gossip_reduce_pallas` — fused gather + coordinate-wise robust
  reduce. The gather ``msgs[nbr_idx]`` is expressed as ``deg_max`` one-hot
  (K, K) matmuls on the MXU (row gathers lower poorly on TPU; a one-hot
  contraction is the idiomatic form), so the received tensor never
  round-trips to HBM — each d-block is gathered and reduced in VMEM.
* :func:`neighbor_reduce_pallas` — the reduce alone, for the per-receiver
  equivocation path where the (K, deg_max, d) received tensor is already
  materialized by the attack.

Blocking: grid over the parameter axis; each program sees the full agent
axis (K is small, padded to the sublane multiple 8) and one lane-aligned
d-block. The median/trimmed reduce builds a (deg_max², K, block_d) rank
network in registers, so ``block_d`` should shrink as deg_max grows
(deg_max² · Kp · block_d · 4B per buffer must fit VMEM); 128 is safe to
K = deg_max = 32.

The reduce body is imported from ``ref.py`` — kernel and oracle share it,
making interpret-mode parity exact by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gossip_reduce.ref import check_mode, cw_reduce


def _gather_reduce_kernel(mode, n_trim, P, msgs_ref, oh_ref, o_ref):
    x = msgs_ref[...].astype(jnp.float32)                # (Kp, bd)
    vals = jnp.stack([
        jax.lax.dot_general(oh_ref[p], x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        for p in range(P)])                              # (P, Kp, bd)
    o_ref[...] = cw_reduce(vals, mode, n_trim)


def _reduce_kernel(mode, n_trim, recv_ref, o_ref):
    o_ref[...] = cw_reduce(recv_ref[...].astype(jnp.float32), mode, n_trim)


@functools.partial(jax.jit, static_argnames=("mode", "n_trim", "block_d",
                                             "interpret"))
def gossip_reduce_pallas(msgs: jnp.ndarray, nbr: jnp.ndarray,
                         mode: str = "mean", n_trim: int = 0,
                         block_d: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """msgs (K, d), nbr (K, P) int -> (K, d) reduced over each receiver's
    neighbor multiset."""
    K, d = msgs.shape
    _, P = nbr.shape
    check_mode(mode, P, n_trim)
    Kp = -(-K // 8) * 8
    dp = -(-d // block_d) * block_d
    mp = jnp.pad(msgs, ((0, Kp - K), (0, dp - d)))
    # (P, Kp, Kp) gather matrices: oh[p, r, s] = 1 iff nbr[r, p] == s
    oh = (nbr[:, :, None] == jnp.arange(K)[None, None, :])
    oh = jnp.pad(oh.astype(jnp.float32).transpose(1, 0, 2),
                 ((0, 0), (0, Kp - K), (0, Kp - K)))
    out = pl.pallas_call(
        functools.partial(_gather_reduce_kernel, mode, n_trim, P),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((Kp, block_d), lambda i: (0, i)),
                  pl.BlockSpec((P, Kp, Kp), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((Kp, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Kp, dp), jnp.float32),
        interpret=interpret,
    )(mp, oh)
    return out[:K, :d]


@functools.partial(jax.jit, static_argnames=("mode", "n_trim", "block_d",
                                             "interpret"))
def neighbor_reduce_pallas(recv: jnp.ndarray, mode: str = "mean",
                           n_trim: int = 0, block_d: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """recv (K, P, d) -> (K, d); reduce over the (already gathered)
    neighbor axis."""
    K, P, d = recv.shape
    check_mode(mode, P, n_trim)
    Kp = -(-K // 8) * 8
    dp = -(-d // block_d) * block_d
    # layout (P, Kp, bd): neighbor axis leading, agents on the sublanes
    rp = jnp.pad(recv.transpose(1, 0, 2),
                 ((0, 0), (0, Kp - K), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, mode, n_trim),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((P, Kp, block_d), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((Kp, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Kp, dp), jnp.float32),
        interpret=interpret,
    )(rp)
    return out[:K, :d]
