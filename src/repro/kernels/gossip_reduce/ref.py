"""Pure-jnp oracle for the masked-neighbor gossip reduce.

One agreement round over the padded neighbor table ``nbr_idx (K, deg_max)``
(DESIGN.md §5): gather each receiver's neighbor messages and reduce them
coordinate-wise — mean, median, or trimmed mean over the neighbor axis.
Padding slots hold the receiver's own index, so every slot is a real
message and no validity masking is needed.

The rank-based reduce body (:func:`cw_reduce`) is shared with the Pallas
kernel, which makes the two paths bit-parity-by-construction for the
median/trimmed modes (O(P²) comparison network, ties broken by slot
index — no sort primitive needed on the VPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MODES = ("mean", "median", "trimmed")


def check_mode(mode: str, deg_max: int, n_trim: int) -> None:
    if mode not in MODES:
        raise ValueError(f"unknown gossip reduce mode {mode!r}; "
                         f"expected one of {MODES}")
    if mode == "trimmed" and not 0 <= 2 * n_trim < deg_max:
        raise ValueError(f"trimmed gossip reduce needs deg_max > 2*n_trim, "
                         f"got deg_max={deg_max}, n_trim={n_trim}")


def cw_reduce(v: jnp.ndarray, mode: str, n_trim: int,
              n_valid: int = None) -> jnp.ndarray:
    """Coordinate-wise reduce of ``v (P, ..., d)`` over its leading axis.

    ``n_valid`` (default: all P) marks a sublane-padded leading axis:
    slots ≥ n_valid are ranked last and excluded, which is how the
    ``trimmed_mean`` kernel reduces its K-padded agent axis through this
    same body (one comparison network, one tie-break rule, everywhere).
    """
    P = v.shape[0]
    n = P if n_valid is None else n_valid
    v = v.astype(jnp.float32)
    tail = (1,) * (v.ndim - 1)
    vld = jax.lax.broadcasted_iota(jnp.int32, (P,) + tail, 0) < n
    if mode == "mean":
        return jnp.sum(jnp.where(vld, v, 0.0), axis=0) / n
    idx = jax.lax.broadcasted_iota(jnp.int32, (P, 1) + tail, 0)
    xv = jnp.where(vld, v, jnp.float32(3.4e38))          # pad slots last
    less = (xv[:, None] < v[None, :]) | (
        (xv[:, None] == v[None, :]) & (idx < idx.swapaxes(0, 1)))
    rank = jnp.sum(less.astype(jnp.int32), axis=0)       # (P, ..., d)
    if mode == "median":
        lo, hi = (n - 1) // 2, n // 2
        pick = lambda r: jnp.sum(jnp.where((rank == r) & vld, v, 0.0),
                                 axis=0)
        return 0.5 * (pick(lo) + pick(hi))
    keep = (rank >= n_trim) & (rank < n - n_trim) & vld
    return jnp.sum(jnp.where(keep, v, 0.0), axis=0) / (n - 2 * n_trim)


def neighbor_reduce(recv: jnp.ndarray, mode: str = "mean",
                    n_trim: int = 0) -> jnp.ndarray:
    """Reduce an already-gathered ``recv (K, P, d)`` tensor to ``(K, d)``."""
    K, P, d = recv.shape
    check_mode(mode, P, n_trim)
    return cw_reduce(recv.transpose(1, 0, 2), mode, n_trim)


def gossip_reduce(msgs: jnp.ndarray, nbr: jnp.ndarray, mode: str = "mean",
                  n_trim: int = 0) -> jnp.ndarray:
    """Fused gather + reduce: ``msgs (K, d)``, ``nbr (K, P)`` -> ``(K, d)``."""
    return neighbor_reduce(msgs[nbr], mode, n_trim)
