"""Dispatched entry points for the masked-neighbor gossip reduce.

``gossip_reduce`` fuses gather + reduce from a (K, d) message matrix over
the padded ``nbr_idx (K, deg_max)`` table; ``neighbor_reduce`` reduces an
already-gathered (K, deg_max, d) tensor (the per-receiver equivocation
path, where no shared message matrix exists).
"""
from repro.kernels.dispatch import register_kernel
from repro.kernels.gossip_reduce import ref
from repro.kernels.gossip_reduce.gossip_reduce import (
    gossip_reduce_pallas, neighbor_reduce_pallas)

gossip_reduce = register_kernel(
    "gossip_reduce", jnp_impl=ref.gossip_reduce,
    pallas_impl=gossip_reduce_pallas, modes=ref.MODES)

neighbor_reduce = register_kernel(
    "neighbor_reduce", jnp_impl=ref.neighbor_reduce,
    pallas_impl=neighbor_reduce_pallas, modes=ref.MODES)
