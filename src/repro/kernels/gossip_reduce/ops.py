"""Dispatched entry points for the masked-neighbor gossip reduce.

``gossip_reduce`` fuses gather + reduce from a (K, d) message matrix over
the padded ``nbr_idx (K, deg_max)`` table; ``neighbor_reduce`` reduces an
already-gathered (K, deg_max, d) tensor (the per-receiver equivocation
path, where no shared message matrix exists).
"""
from repro.kernels.dispatch import register_kernel
from repro.kernels.gossip_reduce import ref
from repro.kernels.gossip_reduce.gossip_reduce import (
    gossip_reduce_pallas, neighbor_reduce_pallas)

# auto-mode size cutoffs (first-operand elements): BENCH_kernels.json has
# the kernel path *losing* to the oracle at (K=8, P=4, D=512) — msgs 4096
# elements for gossip_reduce, recv 16384 for neighbor_reduce — and winning
# from the next ladder point up; below the cutoff auto dispatches jnp.
gossip_reduce = register_kernel(
    "gossip_reduce", jnp_impl=ref.gossip_reduce,
    pallas_impl=gossip_reduce_pallas, modes=ref.MODES,
    auto_jnp_below=8192)

neighbor_reduce = register_kernel(
    "neighbor_reduce", jnp_impl=ref.neighbor_reduce,
    pallas_impl=neighbor_reduce_pallas, modes=ref.MODES,
    auto_jnp_below=32768)
