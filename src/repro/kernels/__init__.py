"""Pallas kernel suite for the robust-aggregation hot path.

Each subpackage is one kernel: ``<name>.py`` (the Pallas body), ``ref.py``
(the pure-jnp oracle), ``ops.py`` (the dispatched entry point). Backend
selection — compiled Pallas on TPU, the Pallas interpreter, or the jnp
oracle — is centralized in :mod:`repro.kernels.dispatch` and exposed as
the ``kernel`` registry namespace (DESIGN.md §6).
"""
