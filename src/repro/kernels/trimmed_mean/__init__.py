from repro.kernels.trimmed_mean import ops, ref
from repro.kernels.trimmed_mean.trimmed_mean import trimmed_mean_pallas
