"""Pure-jnp oracle for the coordinate-wise trimmed mean.

Delegates to the shared rank-network reduce (``gossip_reduce.ref
.cw_reduce`` — O(K²) per coordinate, tie-broken by input index), the same
body the Pallas kernel runs, so oracle and kernel are bit-identical by
construction and the tie-break rule lives in exactly one place.
"""
import jax.numpy as jnp

from repro.kernels.gossip_reduce.ref import cw_reduce


def trimmed_mean(x: jnp.ndarray, n_trim: int) -> jnp.ndarray:
    """x: (K, d) -> (d,): mean over ranks [n_trim, K - n_trim)."""
    return cw_reduce(x, "trimmed", n_trim)
