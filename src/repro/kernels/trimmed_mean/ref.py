"""Pure-jnp oracle for the coordinate-wise trimmed mean.

Rank-based (O(K^2) per coordinate, tie-broken by input index) so the oracle
and the Pallas kernel are bit-identical by construction.
"""
import jax.numpy as jnp


def trimmed_mean(x: jnp.ndarray, n_trim: int) -> jnp.ndarray:
    """x: (K, d) -> (d,): mean over ranks [n_trim, K - n_trim)."""
    K = x.shape[0]
    xf = x.astype(jnp.float32)
    idx = jnp.arange(K)
    less = (xf[:, None, :] < xf[None, :, :]) | (
        (xf[:, None, :] == xf[None, :, :])
        & (idx[:, None, None] < idx[None, :, None]))
    rank = jnp.sum(less, axis=0)                        # (K, d)
    keep = (rank >= n_trim) & (rank < K - n_trim)
    return jnp.sum(jnp.where(keep, xf, 0.0), axis=0) / (K - 2 * n_trim)
