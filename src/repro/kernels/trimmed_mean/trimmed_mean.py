"""Pallas TPU kernel: coordinate-wise trimmed mean over the agent axis.

The robust-aggregation hot-spot for coordinate-wise aggregators: for each of
d coordinates, drop the n_trim smallest and largest of K agent values and
average the rest. K is small (<=32); d is the model dimension (billions).
We tile d into lane-aligned VMEM blocks; the rank-network reduce body
(O(K^2) comparisons, no sort primitive needed on the VPU, tie-broken by
agent index) is shared with the jnp oracle via ``gossip_reduce.ref
.cw_reduce``, with ``n_valid=K`` masking the sublane-padded agent rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gossip_reduce.ref import cw_reduce


def _tm_kernel(n_trim, K, x_ref, o_ref):
    x = x_ref[...]                                      # (Kp, bd)
    o_ref[...] = cw_reduce(x, "trimmed", n_trim, n_valid=K)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("n_trim", "block_d", "interpret"))
def trimmed_mean_pallas(x: jnp.ndarray, n_trim: int, block_d: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    K, d = x.shape
    Kp = -(-K // 8) * 8
    dp = -(-d // block_d) * block_d
    xp = jnp.pad(x, ((0, Kp - K), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(_tm_kernel, n_trim, K),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((Kp, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[0, :d]
