"""Pallas TPU kernel: coordinate-wise trimmed mean over the agent axis.

The robust-aggregation hot-spot for coordinate-wise aggregators: for each of
d coordinates, drop the n_trim smallest and largest of K agent values and
average the rest. K is small (<=32); d is the model dimension (billions).
We tile d into lane-aligned VMEM blocks and compute ranks with an O(K^2)
comparison network (no sort primitive needed on the VPU), tie-broken by
agent index exactly as the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tm_kernel(n_trim, K, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                  # (Kp, bd)
    Kp = x.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (Kp, 1, 1), 0)
    valid = (idx < K)
    big = jnp.float32(3.4e38)
    xv = jnp.where(valid, x[:, None, :], big)           # pad rows rank last
    less = (xv < x[None, :, :]) | (
        (xv == x[None, :, :]) & (idx < idx.transpose(1, 0, 2)))
    rank = jnp.sum(less.astype(jnp.int32), axis=0)      # (Kp, bd)
    keep = (rank >= n_trim) & (rank < K - n_trim) & (valid[:, 0, :] >= 1)
    o_ref[...] = (jnp.sum(jnp.where(keep, x, 0.0), axis=0,
                          keepdims=True) / (K - 2 * n_trim))


@functools.partial(jax.jit,
                   static_argnames=("n_trim", "block_d", "interpret"))
def trimmed_mean_pallas(x: jnp.ndarray, n_trim: int, block_d: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    K, d = x.shape
    Kp = -(-K // 8) * 8
    dp = -(-d // block_d) * block_d
    xp = jnp.pad(x, ((0, Kp - K), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(_tm_kernel, n_trim, K),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((Kp, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[0, :d]
