"""Dispatched entry point for the coordinate-wise trimmed mean.

Backend selection (pallas / pallas-interpret / jnp) lives in
``repro.kernels.dispatch``; override per call with ``backend=`` or globally
via ``REPRO_KERNEL_BACKEND``.
"""
from repro.kernels.dispatch import register_kernel
from repro.kernels.trimmed_mean import ref
from repro.kernels.trimmed_mean.trimmed_mean import trimmed_mean_pallas

# launch-overhead cutoff: under ~2k stack elements the oracle wins
# (BENCH_kernels.json smallest point); auto dispatches jnp below it
trimmed_mean = register_kernel(
    "trimmed_mean", jnp_impl=ref.trimmed_mean,
    pallas_impl=trimmed_mean_pallas, auto_jnp_below=2048)
