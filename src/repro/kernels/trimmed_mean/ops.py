"""Jit'd public entry point for the coordinate-wise trimmed mean."""
import jax

from repro.kernels.trimmed_mean import ref
from repro.kernels.trimmed_mean.trimmed_mean import trimmed_mean_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def trimmed_mean(x, n_trim, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return trimmed_mean_pallas(x, n_trim, interpret=not _on_tpu())
    return ref.trimmed_mean(x, n_trim)
