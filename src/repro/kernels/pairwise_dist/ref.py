"""Pure-jnp oracle for the pairwise_dist kernel."""
import jax.numpy as jnp


def pairwise_sq_dists(x: jnp.ndarray) -> jnp.ndarray:
    """x: (K, d) -> (K, K) squared euclidean distances, float32."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)
