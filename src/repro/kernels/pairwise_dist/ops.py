"""Dispatched entry point for pairwise squared distances.

Backend selection (pallas / pallas-interpret / jnp) lives in
``repro.kernels.dispatch``; the Pallas body is validated in interpret mode
by the kernel test sweep.
"""
from repro.kernels.dispatch import register_kernel
from repro.kernels.pairwise_dist import ref
from repro.kernels.pairwise_dist.pairwise_dist import pairwise_sq_dists_pallas

# below ~2k stack elements the pallas_call launch overhead exceeds the
# whole dense oracle (BENCH_kernels.json smallest-point margins); auto
# falls back to jnp under the cutoff
pairwise_sq_dists = register_kernel(
    "pairwise_dist", jnp_impl=ref.pairwise_sq_dists,
    pallas_impl=pairwise_sq_dists_pallas, auto_jnp_below=2048)
