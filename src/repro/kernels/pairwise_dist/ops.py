"""Jit'd public entry point for pairwise squared distances.

``use_pallas=None`` auto-selects: Pallas (compiled) on TPU, the jnp oracle
elsewhere (the Pallas body itself is validated in interpret mode by the
kernel test sweep).
"""
import jax

from repro.kernels.pairwise_dist import ref
from repro.kernels.pairwise_dist.pairwise_dist import (
    pairwise_sq_dists_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_sq_dists(x, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return pairwise_sq_dists_pallas(x, interpret=not _on_tpu())
    return ref.pairwise_sq_dists(x)
