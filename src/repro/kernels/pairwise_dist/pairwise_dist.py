"""Pallas TPU kernel: Gram-matrix accumulation for pairwise squared
distances of K stacked d-dimensional vectors.

The hot-spot of Krum / MDA / GDA at LLM scale is ``X @ X.T`` over a huge d.
We tile d into VMEM-resident blocks and accumulate the (K, K) Gram matrix on
the MXU; the distance matrix follows from the Gram diagonal. K is padded to
the sublane multiple (8); the d block is a lane multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram(x: jnp.ndarray, block_d: int = 512, interpret: bool = True):
    """x: (K, d) -> (K, K) float32 Gram matrix via d-tiled accumulation."""
    K, d = x.shape
    Kp = -(-K // 8) * 8
    dp = -(-d // block_d) * block_d
    xp = jnp.pad(x, ((0, Kp - K), (0, dp - d)))
    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((Kp, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((Kp, Kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:K, :K]


def pairwise_sq_dists_pallas(x: jnp.ndarray, block_d: int = 512,
                             interpret: bool = True) -> jnp.ndarray:
    g = gram(x, block_d=block_d, interpret=interpret)
    sq = jnp.diag(g)
    d2 = sq[:, None] + sq[None, :] - 2.0 * g
    return jnp.maximum(d2, 0.0)
