from repro.kernels.pairwise_dist import ops, ref
from repro.kernels.pairwise_dist.pairwise_dist import (
    gram, pairwise_sq_dists_pallas)
