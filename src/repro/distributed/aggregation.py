"""Mesh-parallel robust aggregation + averaging agreement on agent-stacked
parameter/gradient pytrees (leading K axis sharded over the federation
axes).

Distance decomposition (DESIGN.md §3): ``||θ_i − θ_l||²`` splits across
model-parallel shards, so each shard contributes a local (K, K) Gram block
and XLA inserts a single psum of K² scalars — full d-vectors never cross
the mesh for Krum / RFA weights / GDA selection. The only O(K·d) collective
is the GDA *mixing* einsum, which is the paper's prescribed all-to-all
parameter exchange (and our §Perf hillclimb target: ``mix_dtype=bf16``
halves its bytes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import Spec, register, resolve


# ---------------------------------------------------------------------------
# Stacked-tree linear algebra
# ---------------------------------------------------------------------------

def stacked_gram(tree) -> jnp.ndarray:
    """Tree with leading K axis -> (K, K) Gram matrix, f32.

    Contracts each leaf over its trailing axes WITHOUT reshaping — a
    (K, ...) x (K, ...) tensordot keeps the model/data shardings on the
    contracted dims intact, so each shard computes a local (K, K) partial
    and XLA inserts one K² psum (a reshape(K, -1) here merges sharded dims
    and forces a full all-gather of every leaf — 16 GB/device at llama-1B).
    """
    leaves = jax.tree.leaves(tree)
    K = leaves[0].shape[0]
    g = jnp.zeros((K, K), jnp.float32)
    for l in leaves:
        axes = tuple(range(1, l.ndim))
        g = g + jax.lax.dot_general(
            l, l, ((axes, axes), ((), ())),
            preferred_element_type=jnp.float32)
    return g


def stacked_gram_blocked(tree, block: int) -> jnp.ndarray:
    """Gram matrix computed in K-blocks: at most ``block`` agents' full
    parameters are ever gathered to a device at once (needed when agent
    params are chip-resident/replicated rather than model-sharded)."""
    leaves = jax.tree.leaves(tree)
    K = leaves[0].shape[0]
    if block <= 0 or K <= block or K % block:
        return stacked_gram(tree)
    n = K // block

    def body(g, i):
        cols = jnp.zeros((K, block), jnp.float32)
        for l in leaves:
            lb = jax.lax.dynamic_slice_in_dim(l, i * block, block, axis=0)
            axes = tuple(range(1, l.ndim))
            cols = cols + jax.lax.dot_general(
                l, lb, ((axes, axes), ((), ())),
                preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice_in_dim(g, cols, i * block,
                                                   axis=1), None

    g, _ = jax.lax.scan(body, jnp.zeros((K, K), jnp.float32),
                        jnp.arange(n))
    return g


def stacked_sq_dists(tree) -> jnp.ndarray:
    g = stacked_gram(tree)
    sq = jnp.diag(g)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)


def stacked_weighted_sum(w: jnp.ndarray, tree, mix_dtype=None):
    """einsum('k,k...->...', w, leaf) per leaf."""
    def f(l):
        lc = l if mix_dtype is None else l.astype(mix_dtype)
        out = jnp.einsum("k,k...->...", w.astype(jnp.float32),
                         lc.astype(jnp.float32))
        return out.astype(l.dtype)
    return jax.tree.map(f, tree)


def stacked_mix(W: jnp.ndarray, tree, mix_dtype=None, block: int = 0):
    """Row-stochastic mixing: leaf'_k = Σ_l W[k,l] leaf_l.

    This is the O(K·d) all-to-all parameter exchange of Avg-Agree;
    ``mix_dtype=jnp.bfloat16`` sends bf16 messages and ``block > 0``
    streams the exchange in K-blocks so at most ``block`` agents' params
    are gathered to a device at once (both beyond-paper opts, §Perf).
    """
    K = jax.tree.leaves(tree)[0].shape[0]

    def f(l):
        lc = l if mix_dtype is None else l.astype(mix_dtype)
        out = jnp.einsum("kl,l...->k...", W.astype(lc.dtype), lc,
                         preferred_element_type=jnp.float32)
        return out.astype(l.dtype)

    if block <= 0 or K <= block or K % block:
        return jax.tree.map(f, tree)
    n = K // block
    leaves, treedef = jax.tree.flatten(tree)

    def body(acc, i):
        Wb = jax.lax.dynamic_slice_in_dim(W, i * block, block, axis=1)
        new = []
        for a, l in zip(acc, leaves):
            lb = jax.lax.dynamic_slice_in_dim(l, i * block, block, axis=0)
            lc = lb if mix_dtype is None else lb.astype(mix_dtype)
            part = jnp.einsum("kl,l...->k...", Wb.astype(lc.dtype), lc,
                              preferred_element_type=jnp.float32)
            new.append(a + part)
        return new, None

    acc0 = [jnp.zeros(l.shape, jnp.float32) for l in leaves]
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(n))
    return jax.tree.unflatten(
        treedef, [a.astype(l.dtype) for a, l in zip(acc, leaves)])


def _broadcast_rows(tree_single, K: int):
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (K,) + l.shape), tree_single)


# ---------------------------------------------------------------------------
# Sharded flat-(K, D) execution layer
# ---------------------------------------------------------------------------
# The registry aggregators (``repro.core.aggregators``) route here when
# their (K, D) input carries a NamedSharding that splits D over more than
# one device: all vector math runs through local-shard Gram contributions
# (one K² psum) and shard-local weighted sums / coordinate-wise reduces, so
# the full stack is never gathered to a device — per-device footprint is
# O(K² + K·D/devices). A bare (K, D) array is a valid single-leaf tree, so
# these reuse ``stacked_gram``/``stacked_gram_blocked`` directly.

def dim_sharded(x, axis: int = -1) -> bool:
    """True when ``x`` is a concrete array whose ``axis`` is split by a
    NamedSharding over more than one device.

    Trace-time tracers have no sharding — inside jit callers must pass
    their ``sharded=`` intent explicitly (the detection is eager-only by
    design: dispatch is a trace-time decision, like the kernel backend).
    """
    try:
        sh = x.sharding
    except Exception:
        return False
    if not isinstance(sh, jax.sharding.NamedSharding):
        return False
    spec = sh.spec
    ax = axis % max(x.ndim, 1)
    if len(spec) <= ax or spec[ax] is None:
        return False
    names = spec[ax] if isinstance(spec[ax], tuple) else (spec[ax],)
    return int(np.prod([sh.mesh.shape[n] for n in names])) > 1


def flat_sq_dists(x: jnp.ndarray, block: int = 0) -> jnp.ndarray:
    """(K, D) -> (K, K) squared distances via the shard-local Gram path."""
    g = stacked_gram_blocked(x, block) if block else stacked_gram(x)
    sq = jnp.diag(g)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)


def flat_krum(x: jnp.ndarray, n_byz: int, m: int = 1,
              block: int = 0) -> jnp.ndarray:
    """(Multi-)Krum on a sharded flat stack: scores from the K×K Gram
    matrix; the m-way selection is a (K,) weighted sum, which keeps the
    winner's D-sharding instead of gathering rows."""
    from repro.kernels.krum_score.ref import scores_from_d2
    K = x.shape[0]
    scores = scores_from_d2(flat_sq_dists(x, block), max(K - n_byz - 2, 1))
    if m == 1:
        w = jax.nn.one_hot(jnp.argmin(scores), K, dtype=jnp.float32)
    else:
        _, idx = jax.lax.top_k(-scores, m)
        w = jnp.zeros((K,), jnp.float32).at[idx].set(1.0 / m)
    return stacked_weighted_sum(w, x)


def flat_rfa(x: jnp.ndarray, n_iter: int = 32, nu=1e-6,
             block: int = 0) -> jnp.ndarray:
    """Smoothed Weiszfeld on a sharded flat stack: the iteration runs
    entirely in (K,) weight space from the Gram matrix (same decomposition
    as the Pallas kernel); one final weighted sum materializes z."""
    K = x.shape[0]
    g = stacked_gram_blocked(x, block) if block else stacked_gram(x)
    sq = jnp.diag(g)

    def body(_, w):
        gw = g @ w
        d2 = jnp.maximum(sq - 2.0 * gw + w @ gw, 0.0)
        iw = 1.0 / jnp.sqrt(d2 + nu)
        return iw / jnp.sum(iw)

    w = jax.lax.fori_loop(0, n_iter, body,
                          jnp.full((K,), 1.0 / K, jnp.float32))
    return stacked_weighted_sum(w, x)


def flat_trimmed_mean(x: jnp.ndarray, n_trim: int) -> jnp.ndarray:
    """Coordinate-wise trimmed mean — shard-local by construction; runs
    the oracle's rank-network body (bit-identical to the kernel)."""
    from repro.kernels.trimmed_mean.ref import trimmed_mean as tm_ref
    return tm_ref(x, n_trim)


# ---------------------------------------------------------------------------
# Robust aggregators on stacked trees (broadcast-consistent adversary)
# ---------------------------------------------------------------------------

def agg_mean(tree, n_byz: int = 0, key=None):
    K = jax.tree.leaves(tree)[0].shape[0]
    return _broadcast_rows(jax.tree.map(lambda l: jnp.mean(l, 0), tree), K)


def agg_krum(tree, n_byz: int, key=None):
    K = jax.tree.leaves(tree)[0].shape[0]
    d2 = stacked_sq_dists(tree)
    n_near = max(K - n_byz - 2, 1)
    near = jnp.sort(d2, axis=1)[:, 1:n_near + 1]
    winner = jnp.argmin(jnp.sum(near, axis=1))
    sel = jax.nn.one_hot(winner, K, dtype=jnp.float32)
    return _broadcast_rows(stacked_weighted_sum(sel, tree), K)


def agg_rfa(tree, n_byz: int = 0, key=None, n_iter: int = 8,
            nu: float = 1e-6):
    """Smoothed Weiszfeld on stacked trees: per iteration one (K,) weight
    vector from shard-decomposed distances + one weighted-sum collective."""
    K = jax.tree.leaves(tree)[0].shape[0]
    g = stacked_gram(tree)
    sq = jnp.diag(g)
    w = jnp.full((K,), 1.0 / K, jnp.float32)
    # dist²(x_k, z) with z = Σ w_l x_l decomposes over the Gram matrix:
    # ||x_k||² − 2 Σ_l w_l G[k,l] + wᵀ G w  — no extra collectives.
    for _ in range(n_iter):
        dz = jnp.sqrt(jnp.maximum(
            sq - 2.0 * g @ w + w @ g @ w, 0.0) + nu)
        w = (1.0 / dz) / jnp.sum(1.0 / dz)
    return _broadcast_rows(stacked_weighted_sum(w, tree), K)


def agg_trimmed_mean(tree, n_byz: int, key=None):
    """Coordinate-wise trimmed mean — shard-local (commutes with sharding)."""
    K = jax.tree.leaves(tree)[0].shape[0]
    n = min(n_byz, (K - 1) // 2)
    if n == 0:
        return agg_mean(tree)

    def f(l):
        s = jnp.sort(l.astype(jnp.float32), axis=0)[n:K - n]
        return jnp.mean(s, axis=0).astype(l.dtype)

    return _broadcast_rows(jax.tree.map(f, tree), K)


register("fed_aggregator", "mean")(lambda: agg_mean)
register("fed_aggregator", "krum")(lambda: agg_krum)
register("fed_aggregator", "trimmed_mean")(lambda: agg_trimmed_mean)


# fed_* components feed the transformer-scale train step, which has no
# lane-batching path — every scalar is deliberately static_kwargs so the
# registry kwarg audit (engine lane tests) stays exhaustive: n_iter is a
# Python loop trip count; sigma/scale/nu could only become traced here by
# threading a traced= plumb through fed_train_step (not worth it for a
# step that runs one config at a time).
@register("fed_aggregator", "rfa", static_kwargs=("n_iter", "nu"))
def _fed_rfa_factory(n_iter: int = 8, nu: float = 1e-6):
    return functools.partial(agg_rfa, n_iter=n_iter, nu=nu)


def aggregate(name, tree, n_byz: int, key=None):
    """Resolve a stacked-tree aggregator spec (name, spec string like
    ``"rfa(n_iter=16)"``, or Spec) and apply it."""
    return resolve("fed_aggregator", name)(tree, n_byz=n_byz, key=key)


# ---------------------------------------------------------------------------
# GDA averaging agreement on stacked trees
# ---------------------------------------------------------------------------

def gda_mix_matrix(d2: jnp.ndarray, n_keep: int) -> jnp.ndarray:
    """Per-agent greedy selection: W[k, l] = 1/n_keep for the n_keep agents
    closest to agent k (self included: d2[k,k] = 0)."""
    K = d2.shape[0]
    _, idx = jax.lax.top_k(-d2, n_keep)
    W = jnp.zeros((K, K), jnp.float32)
    W = W.at[jnp.arange(K)[:, None], idx].set(1.0 / n_keep)
    return W


def gda_agree(tree, kappa: int, alpha_bar: float = 0.2,
              mix_dtype: Optional[jnp.dtype] = None, block: int = 0):
    """κ rounds of GDA averaging agreement on an agent-stacked tree."""
    K = jax.tree.leaves(tree)[0].shape[0]
    if K == 1 or kappa == 0:
        return tree
    n_keep = max(int((1.0 - alpha_bar) * K + 0.999), 1)

    def sq_dists(t):
        g = stacked_gram_blocked(t, block) if block else stacked_gram(t)
        sq = jnp.diag(g)
        return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)

    def one_round(t, _):
        W = gda_mix_matrix(sq_dists(t), n_keep)
        return stacked_mix(W, t, mix_dtype=mix_dtype, block=block), None

    if kappa <= 8:
        # unrolled: each round's mixing collectives appear explicitly in
        # the HLO, so the dry-run roofline counts the paper's O(K²)
        # agreement communication exactly (a lax.scan hides them in a
        # while body, which HLO cost analysis counts once)
        for _ in range(kappa):
            tree, _ = one_round(tree, None)
        return tree
    tree, _ = jax.lax.scan(one_round, tree, None, length=kappa)
    return tree


# ---------------------------------------------------------------------------
# Stacked-tree Byzantine attacks (for examples / resilience tests)
# ---------------------------------------------------------------------------

def _byz_to(byz_mask, l):
    return byz_mask.reshape(byz_mask.shape + (1,) * (l.ndim - 1))


@register("fed_attack", "none")
def _fed_none_factory():
    return lambda tree, byz_mask, key: tree


@register("fed_attack", "large_noise", static_kwargs=("sigma",))
def _fed_large_noise_factory(sigma: float = 100.0):
    def fn(tree, byz_mask, key):
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(key, len(leaves))
        new = [jnp.where(_byz_to(byz_mask, l), sigma * jax.random.normal(
            k, l.shape, l.dtype), l) for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, new)
    return fn


@register("fed_attack", "avg_zero")
def _fed_avg_zero_factory():
    def fn(tree, byz_mask, key):
        n_byz = jnp.maximum(jnp.sum(byz_mask), 1)

        def f(l):
            m = _byz_to(byz_mask, l)
            hsum = jnp.sum(jnp.where(m, 0.0, l), axis=0)
            return jnp.where(m, (-hsum / n_byz)[None], l)
        return jax.tree.map(f, tree)
    return fn


@register("fed_attack", "sign_flip", static_kwargs=("scale",))
def _fed_sign_flip_factory(scale: float = 3.0):
    def fn(tree, byz_mask, key):
        n_h = jnp.maximum(jnp.sum(~byz_mask), 1)

        def f(l):
            m = _byz_to(byz_mask, l)
            mu = jnp.sum(jnp.where(m, 0.0, l), axis=0) / n_h
            return jnp.where(m, (-scale * mu)[None], l)
        return jax.tree.map(f, tree)
    return fn


def attack_stacked(name, tree, byz_mask, key):
    """Resolve a stacked-tree attack spec (name, spec string like
    ``"large_noise(sigma=10)"``, or Spec) and apply it."""
    if name is None:
        return tree
    return resolve("fed_attack", name)(tree, byz_mask, key)
