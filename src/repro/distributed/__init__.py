from repro.distributed import aggregation, sharding
from repro.distributed.fed_trainer import (FedConfig, FedState,
                                           common_sample_coin,
                                           fed_state_shardings,
                                           fed_train_step, init_fed_state,
                                           make_fed_step)
