"""Federated DecByzPG trainer for the assigned architectures.

State layout: every agent's parameters/optimizer state carry a leading K
axis sharded over the federation axes (DESIGN.md §3), so this *is* the
decentralized algorithm — no chip holds another agent's state; the robust
aggregation and GDA agreement are the only cross-agent collectives.

Per step (the PAGE coin is drawn host-side by Common-Sample and selects one
of two compiled programs):
  large (c=1): ṽ^(k) = ∇CE(θ^(k); batch_k)
  small (c=0): ṽ^(k) = ∇CE(θ^(k); b_k) − ∇CE(θ_prev^(k); b_k) + v_prev^(k)
then: attack → robust-aggregate → per-agent optimizer step → Avg-Agree_κ.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.aggregators import rejection_mask
from repro.core.registry import normalize_spec_fields
from repro.distributed import aggregation as agg_lib
from repro.distributed.sharding import (batch_spec, fed_axes, n_agents,
                                        param_shardings)
from repro.models.model import init_params, lm_loss, lm_loss_labeled
from repro.optim.optimizers import get_optimizer


@dataclasses.dataclass(frozen=True)
class FedConfig:
    aggregator: object = "rfa"       # str | Spec, normalized to Spec
    kappa: int = 4
    alpha_bar: float = 0.2
    n_byz: int = 0
    attack: object = "none"
    lr: float = 1e-4
    optimizer: object = "adam"
    page_p: float = 0.1              # Common-Sample coin probability
    mix_dtype: Optional[str] = None  # None | "bfloat16" (§Perf opt)
    mix_block: int = 0               # stream agreement in K-blocks (§Perf)
    seed: int = 0
    telemetry: bool = False          # static: in-step obs taps + phases;
    # off = the exact pre-telemetry program (no debug_callback in jaxpr)

    def __post_init__(self):
        normalize_spec_fields(self, ("aggregator", "attack", "optimizer"))


class FedState(NamedTuple):
    params: object       # agent-stacked (K, ...)
    prev_params: object
    v: object            # running PAGE direction, agent-stacked
    opt_state: object
    step: jnp.ndarray


def init_fed_state(cfg: ModelConfig, fed: FedConfig, K: int, key,
                   dtype=jnp.float32) -> FedState:
    p0 = init_params(cfg, key, dtype)
    stack = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (K,) + l.shape),
                         p0)                      # common init θ_0
    opt = get_optimizer(fed.optimizer, fed.lr, maximize=False)
    opt_state = jax.vmap(opt.init)(stack)
    v = jax.tree.map(jnp.zeros_like, stack)
    return FedState(stack, stack, v, opt_state, jnp.zeros((), jnp.int32))


def _loss(cfg, params, batch):
    if "labels" in batch:
        return lm_loss_labeled(cfg, params, batch["tokens"],
                               batch["labels"], batch.get("prefix_embeds"))
    return lm_loss(cfg, params, batch["tokens"],
                   batch.get("prefix_embeds"))


def fed_train_step(cfg: ModelConfig, fed: FedConfig, state: FedState,
                   batch, byz_mask, key, *, large) -> tuple:
    """batch: {'tokens': (K, b, S)[, 'prefix_embeds': (K, b, P, D)]}.

    ``large`` is either a Python bool (static — two compiled programs, the
    PAGE switch resolved by the host-side Common-Sample coin, the legacy
    driver) or a traced boolean scalar (one compiled program with a
    ``lax.cond`` PAGE switch — the fused-window driver, where the coin is
    drawn inside the scan).
    Returns (new_state, metrics).
    """
    grad_fn = jax.grad(lambda p, b: _loss(cfg, p, b))
    loss_fn = jax.value_and_grad(lambda p, b: _loss(cfg, p, b))

    with obs.named_phase("fed.estimate", fed.telemetry):
        losses, g_new = jax.vmap(loss_fn)(state.params, batch)

        def _page(_):
            g_old = jax.vmap(grad_fn)(state.prev_params, batch)
            return jax.tree.map(lambda a, b, c: a - b + c,
                                g_new, g_old, state.v)

        if isinstance(large, (bool, int)):
            tilde_v = g_new if large else _page(None)
        else:
            tilde_v = jax.lax.cond(large, lambda _: g_new, _page, None)

    K = byz_mask.shape[0]
    k_att, k_agg = jax.random.split(key)
    with obs.named_phase("fed.aggregate", fed.telemetry):
        if K == 1:
            v = tilde_v    # single-agent federation: aggregation is identity
        else:
            tilde_v = agg_lib.attack_stacked(fed.attack, tilde_v, byz_mask,
                                             k_att)
            v = agg_lib.aggregate(fed.aggregator, tilde_v, fed.n_byz, k_agg)

    opt = get_optimizer(fed.optimizer, fed.lr, maximize=False)
    new_params, new_opt = jax.vmap(opt.update)(v, state.opt_state,
                                               state.params)
    mix_dtype = jnp.bfloat16 if fed.mix_dtype == "bfloat16" else None
    with obs.named_phase("fed.agree", fed.telemetry):
        new_params = agg_lib.gda_agree(new_params, fed.kappa, fed.alpha_bar,
                                       mix_dtype=mix_dtype,
                                       block=fed.mix_block)

    metrics = {
        "loss": jnp.mean(jnp.where(byz_mask, 0.0, losses))
        * byz_mask.shape[0] / jnp.maximum(jnp.sum(~byz_mask), 1),
        # K=1: diameter is identically 0 (and the pairwise tensordot would
        # force an all-gather of the full parameter stack)
        "diameter": (jnp.zeros(()) if K == 1 else jnp.sqrt(jnp.max(
            agg_lib.stacked_sq_dists(new_params)))),
    }
    if fed.telemetry:
        # observers only: per-agent honest gradient norms, computed
        # leaf-wise so the model-sharded stacks are never gathered
        sq = sum(jnp.sum(jnp.reshape(l, (K, -1)) ** 2, axis=1)
                 for l in jax.tree.leaves(tilde_v))
        metrics["grad_norm"] = jnp.sum(
            jnp.where(byz_mask, 0.0, jnp.sqrt(sq))) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        obs.tap("fed", step=state.step, loss=metrics["loss"],
                diameter=metrics["diameter"],
                grad_norm=metrics["grad_norm"])
    new_state = FedState(new_params, state.params, v, new_opt,
                         state.step + 1)
    return new_state, metrics


def fed_state_shardings(cfg: ModelConfig, state_shape: FedState, mesh):
    """NamedShardings for a FedState shape tree (opt_state m/v mirror the
    stacked parameter rules; scalar counters are replicated)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    pshard = lambda tree: param_shardings(cfg, tree, mesh, stacked=True)
    opt = state_shape.opt_state
    if hasattr(opt, "m") and hasattr(opt, "v"):          # AdamState
        opt_sh = type(opt)(rep, pshard(opt.m), pshard(opt.v))
    elif hasattr(opt, "m"):                              # MomentumState
        opt_sh = type(opt)(pshard(opt.m))
    else:
        opt_sh = jax.tree.map(lambda _: rep, opt)
    return FedState(pshard(state_shape.params),
                    pshard(state_shape.prev_params),
                    pshard(state_shape.v), opt_sh, rep)


def make_fed_step(cfg: ModelConfig, fed: FedConfig, mesh, *, large: bool,
                  dtype=jnp.float32, per_agent_batch: int = 8,
                  seq_len: int = 512, key=None):
    """jit'd federated step with mesh shardings (used by launch + dry-run).

    Returns (jitted_step, state_shape, batch_shape, shardings dict).

    ``key`` shapes the FedState tree (consumed only under
    ``jax.eval_shape``): pass the caller's init key — or a
    ``ShapeDtypeStruct`` — to make the stream explicit; ``None`` uses an
    abstract key struct, so no literal PRNG key is baked in here.
    """
    from jax.sharding import NamedSharding
    K = n_agents(cfg, mesh)
    if key is None:
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state_shape = jax.eval_shape(
        lambda k: init_fed_state(cfg, fed, K, k, dtype), key)
    state_sh = fed_state_shardings(cfg, state_shape, mesh)
    b_sh = NamedSharding(mesh, batch_spec(cfg, mesh, stacked=True))
    rep = NamedSharding(mesh, jax.sharding.PartitionSpec())

    batch = {"tokens": jax.ShapeDtypeStruct((K, per_agent_batch, seq_len),
                                            jnp.int32),
             "labels": jax.ShapeDtypeStruct((K, per_agent_batch, seq_len),
                                            jnp.int32)}
    batch_sh = {"tokens": b_sh, "labels": b_sh}
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (K, per_agent_batch, cfg.n_prefix_embeds, cfg.d_model), dtype)
        batch["tokens"] = jax.ShapeDtypeStruct(
            (K, per_agent_batch, seq_len - cfg.n_prefix_embeds), jnp.int32)
        batch["labels"] = batch["tokens"]
        batch_sh["prefix_embeds"] = b_sh

    step = jax.jit(
        lambda state, b, mask, key: fed_train_step(
            cfg, fed, state, b, mask, key, large=large),
        in_shardings=(state_sh, batch_sh, rep, rep),
        out_shardings=(state_sh, None),
        donate_argnums=(0,))
    return step, state_shape, batch, (state_sh, batch_sh, rep)


# ---------------------------------------------------------------------------
# Flat (K, D) federated trainer — transformer-scale robust aggregation
# ---------------------------------------------------------------------------
# The tree-shaped trainer above keeps each leaf model-sharded by the leaf
# rules. The flat trainer instead ravels every agent's parameters into one
# (K, D) stack with D sharded over the "model" axis — the layout the
# registry aggregators' sharded execution layer (DESIGN.md §3,
# ``repro.distributed.aggregation``) operates on: robust aggregation costs
# one K² psum plus shard-local weighted sums, never a parameter gather.


class FlatFedState(NamedTuple):
    theta: jnp.ndarray   # (K, D) flat agent-stacked params (D-sharded)
    prev: jnp.ndarray
    v: jnp.ndarray       # running PAGE direction, (K, D)
    opt_state: object
    step: jnp.ndarray


def flat_param_sharding(mesh):
    """NamedSharding splitting the trailing D axis of (K, D) stacks over
    the mesh's "model" axis (agents replicated)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(None, "model"))


def init_flat_fed_state(cfg: ModelConfig, fed: FedConfig, K: int, key,
                        dtype=jnp.float32, mesh=None):
    """Common-init flat state. Returns ``(state, unravel)`` where
    ``unravel(row) -> params tree`` recovers one agent's parameters.

    With a mesh whose "model" axis spans >1 device, the (K, D) stacks are
    placed D-sharded, which is what routes the registry aggregators onto
    the sharded Gram path.
    """
    from jax.flatten_util import ravel_pytree
    vec0, unravel = ravel_pytree(init_params(cfg, key, dtype))
    theta = jnp.tile(vec0, (K, 1))
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        theta = jax.device_put(theta, flat_param_sharding(mesh))
    opt = get_optimizer(fed.optimizer, fed.lr, maximize=False)
    return FlatFedState(theta, jnp.array(theta), jnp.zeros_like(theta),
                        jax.vmap(opt.init)(theta),
                        jnp.zeros((), jnp.int32)), unravel


def flat_fed_state_shardings(mesh, state_shape: FlatFedState):
    """NamedShardings for a FlatFedState shape tree: every (K, D) stack
    D-sharded, scalar counters replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    sh = flat_param_sharding(mesh)
    opt_sh = jax.tree.map(
        lambda l: sh if getattr(l, "ndim", 0) == 2 else rep,
        state_shape.opt_state)
    return FlatFedState(sh, sh, sh, opt_sh, rep)


def fed_train_step_flat(cfg: ModelConfig, fed: FedConfig,
                        state: FlatFedState, unravel, batch, byz_mask,
                        key, *, large, sharded: Optional[bool] = None
                        ) -> tuple:
    """One federated step on the flat (K, D) stack.

    Same protocol as :func:`fed_train_step` (PAGE → attack → robust
    aggregate → per-agent optimizer → GDA agreement), but the aggregation
    runs the *registry* aggregators (``repro.core.aggregators``) over the
    flat stack. ``sharded=True`` forces their sharded Gram path from
    inside jit (detection is eager-only); the aggregate is broadcast back
    to all K rows, matching the broadcast-consistent adversary of the
    tree path.
    """
    from repro.core.registry import resolve as _resolve

    def loss_vec(vec, b):
        return _loss(cfg, unravel(vec), b)

    with obs.named_phase("fed.estimate", fed.telemetry):
        losses, g_new = jax.vmap(jax.value_and_grad(loss_vec))(state.theta,
                                                               batch)

        def _page(_):
            g_old = jax.vmap(jax.grad(loss_vec))(state.prev, batch)
            return g_new - g_old + state.v

        if isinstance(large, (bool, int)):
            tilde_v = g_new if large else _page(None)
        else:
            tilde_v = jax.lax.cond(large, lambda _: g_new, _page, None)

    K = byz_mask.shape[0]
    k_att, k_agg = jax.random.split(key)
    with obs.named_phase("fed.aggregate", fed.telemetry):
        if K == 1:
            v = tilde_v
        else:
            tilde_v = agg_lib.attack_stacked(fed.attack, tilde_v, byz_mask,
                                             k_att)
            agg = _resolve("aggregator", fed.aggregator, K=K,
                           n_byz=fed.n_byz, sharded=sharded)
            v = jnp.broadcast_to(agg(tilde_v, k_agg)[None],
                                 state.theta.shape)

    opt = get_optimizer(fed.optimizer, fed.lr, maximize=False)
    new_theta, new_opt = jax.vmap(opt.update)(v, state.opt_state,
                                              state.theta)
    mix_dtype = jnp.bfloat16 if fed.mix_dtype == "bfloat16" else None
    with obs.named_phase("fed.agree", fed.telemetry):
        new_theta = agg_lib.gda_agree(new_theta, fed.kappa, fed.alpha_bar,
                                      mix_dtype=mix_dtype,
                                      block=fed.mix_block)
    metrics = {
        "loss": jnp.mean(jnp.where(byz_mask, 0.0, losses))
        * K / jnp.maximum(jnp.sum(~byz_mask), 1),
        "diameter": (jnp.zeros(()) if K == 1 else jnp.sqrt(jnp.max(
            agg_lib.stacked_sq_dists(new_theta)))),
    }
    if fed.telemetry:
        norms = jnp.linalg.norm(tilde_v, axis=1)
        metrics["grad_norm"] = jnp.sum(jnp.where(byz_mask, 0.0, norms)) \
            / jnp.maximum(jnp.sum(~byz_mask), 1)
        # the flat (K, D) stack is what the suspicion scores operate on —
        # the tree-shaped trainer has no rejected-mask plane
        metrics["rejected"] = (jnp.zeros((K,), bool) if K == 1 else
                               rejection_mask(fed.aggregator, tilde_v,
                                              fed.n_byz))
        obs.tap("fed", step=state.step, loss=metrics["loss"],
                diameter=metrics["diameter"],
                grad_norm=metrics["grad_norm"],
                rejected=metrics["rejected"])
    return FlatFedState(new_theta, state.theta, v, new_opt,
                        state.step + 1), metrics


def fed_coin_key(fed: FedConfig):
    """Coin key of the fused window's in-scan Common-Sample stream (the
    per-step replay in tests derives identical coins from it)."""
    return jax.random.fold_in(jax.random.PRNGKey(fed.seed), 0x0C01)


def fed_train_window(cfg: ModelConfig, fed: FedConfig, state: FedState,
                     batches, byz_mask, ts, key) -> tuple:
    """Fused multi-step driver: ``lax.scan`` a window of W federated steps
    in one program (DESIGN.md §2).

    batches: the per-step batch tree stacked on a leading W axis
    ((W, K, b, S) tokens/labels); ts: (W,) global step indices.  The PAGE
    coin is drawn inside the scan from the fold of a seed-derived coin key
    (``engine.page_coin``), so the window needs no host round-trip per
    iteration.  Returns (final state, metrics stacked (W,)).
    """
    from repro.core import engine
    coin_key = fed_coin_key(fed)

    def body(st, xs):
        batch, t = xs
        coin = engine.page_coin(coin_key, t, fed.page_p)
        st, metrics = fed_train_step(cfg, fed, st, batch, byz_mask,
                                     jax.random.fold_in(key, t), large=coin)
        return st, dict(metrics, coin=coin)

    return jax.lax.scan(body, state, (batches, ts))


def common_sample_coin(step: int, seed: int, p: float) -> bool:
    """Common-Sample: the paper's shared PRNG coin (host-level, derived from
    the common initialization seed; the legacy per-step driver — the fused
    window draws its coin in-scan via ``repro.core.engine.page_coin``)."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    return bool(step == 0 or rng.random() < p)
