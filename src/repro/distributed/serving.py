"""Serving steps: prefill and single-token decode, mesh-sharded.

decode shapes lower ``decode_step`` — one new token against a KV/state
cache of ``seq_len``; ``long_500k`` allocates a sliding-window ring of
``cfg.long_context_window`` instead (sub-quadratic + sub-linear memory),
and recurrent families carry O(1) state.

This module also carries the slot-granular cache ops used by the
continuous-batching engine (``repro.serving``): inserting one request's
prefilled ring into a slot of a per-slot cache, and evicting a finished
slot (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import cache_shardings, param_shardings
from repro.models.model import (decode_step, init_cache, init_params,
                                prefill)


def serve_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring size for a decode cache over a context of ``seq_len``."""
    if cfg.family == "ssm":
        return 1                       # recurrent state only
    if seq_len > 65536:                # long-context: sliding-window ring
        return cfg.long_context_window
    return seq_len


@dataclasses.dataclass(frozen=True)
class ServeFns:
    """The typed return of :func:`make_serve_fns`.

    * ``prefill(params, tokens[, prefix_embeds]) -> (logits, cache)``
    * ``decode(params, token, cache) -> (logits, cache)``
    * ``shardings`` — ``NamedSharding`` trees for ``params`` / ``cache``
      plus the batch ``PartitionSpec``
    * ``cache_shape`` / ``params_shape`` — ``ShapeDtypeStruct`` trees

    One release of tuple-compatibility: unpacking as the historical
    ``(prefill, decode, specs)`` triple still works but warns — move to
    attribute access.
    """
    prefill: Callable
    decode: Callable
    shardings: dict
    cache_shape: Any
    params_shape: Any
    batch_spec: Any

    @property
    def specs(self) -> dict:
        """The legacy specs dict of the ``(fn, fn, dict)`` era."""
        return {"params": self.shardings["params"],
                "cache": self.shardings["cache"],
                "cache_shape": self.cache_shape,
                "params_shape": self.params_shape,
                "batch_spec": self.batch_spec}

    def __iter__(self):
        warnings.warn(
            "unpacking make_serve_fns() as a (prefill, decode, specs) "
            "tuple is deprecated — use the ServeFns fields "
            "(.prefill/.decode/.shardings/.cache_shape/.params_shape)",
            DeprecationWarning, stacklevel=2)
        return iter((self.prefill, self.decode, self.specs))


def make_serve_fns(cfg: ModelConfig, mesh, batch: int, seq_len: int,
                   dtype=jnp.float32, *, key=None) -> ServeFns:
    """Build mesh-sharded prefill/decode programs as a :class:`ServeFns`.

    ``key`` shapes the parameter tree (it is only ever consumed under
    ``jax.eval_shape``): pass the caller's init key — or a
    ``ShapeDtypeStruct`` — to make the stream explicit; ``None`` uses an
    abstract key struct, so no literal PRNG key is baked in here.
    """
    if key is None:
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch_shards = 1
    for a in axes:
        n_batch_shards *= mesh.shape[a]
    if batch % max(n_batch_shards, 1) != 0:
        axes = ()                      # e.g. long_500k batch=1: replicate
    b_spec = P(axes if axes else None)
    rep = NamedSharding(mesh, P())
    p_sh = lambda shape: param_shardings(cfg, shape, mesh, stacked=False)
    W = serve_cache_len(cfg, seq_len)

    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, batch, W, dtype))
    c_sh = cache_shardings(cfg, cache_shape, mesh)
    params_shape = jax.eval_shape(lambda k: init_params(cfg, k, dtype),
                                  key)
    psh = p_sh(params_shape)

    def _prefill(params, tokens, prefix_embeds=None):
        return prefill(cfg, params, tokens, prefix_embeds, cache_len=W)

    def _decode(params, token, cache):
        return decode_step(cfg, params, token, cache)

    # prefill cache out-sharding is left to propagation (requesting the
    # ring layout here forces an SPMD full-rematerialization inside the
    # layer scan); decode's explicit in_shardings re-lay it out once.
    prefill_jit = jax.jit(
        _prefill,
        in_shardings=(psh, NamedSharding(mesh, b_spec), None)
        if cfg.frontend != "none" else (psh, NamedSharding(mesh, b_spec)),
        out_shardings=(NamedSharding(mesh, b_spec), None))
    decode_jit = jax.jit(
        _decode,
        in_shardings=(psh, NamedSharding(mesh, b_spec), c_sh),
        out_shardings=(NamedSharding(mesh, b_spec), c_sh),
        donate_argnums=(2,))
    return ServeFns(
        prefill=prefill_jit, decode=decode_jit,
        shardings={"params": psh, "cache": c_sh, "batch_spec": b_spec},
        cache_shape=cache_shape, params_shape=params_shape,
        batch_spec=b_spec)


# ---------------------------------------------------------------------------
# Slot-granular cache ops (continuous batching, DESIGN.md §11)
# ---------------------------------------------------------------------------

def slot_cache_insert(cache, row, slot, true_len):
    """Insert a batch-1 prefill cache ``row`` into ``slot`` of a per-slot
    cache (:func:`repro.models.model.init_slot_cache` layout).

    ``true_len`` is the number of *real* prompt positions (prefix embeds
    included); ring entries holding positions ``>= true_len`` — prompt
    padding written by a bucketed prefill — are marked empty, so padded
    keys can never be attended to.  ``slot`` and ``true_len`` may be
    traced scalars: one compiled insert program serves every slot and
    every prompt length.
    """
    sp = jnp.where((row["slot_pos"] >= 0) & (row["slot_pos"] < true_len),
                   row["slot_pos"], -1)
    blocks = jax.tree.map(lambda c, r: c.at[:, slot].set(r[:, 0]),
                          cache["blocks"], row["blocks"])
    return {"pos": cache["pos"].at[slot].set(true_len),
            "slot_pos": cache["slot_pos"].at[slot].set(sp),
            "blocks": blocks}


def slot_cache_evict(cache, slot):
    """Clear one slot: empty ring (``slot_pos = -1``), position 0.  Block
    contents are left in place — they are unreachable through the empty
    ring and the next :func:`slot_cache_insert` overwrites them."""
    return {"pos": cache["pos"].at[slot].set(0),
            "slot_pos": cache["slot_pos"].at[slot].set(-1),
            "blocks": cache["blocks"]}
