"""Serving steps: prefill and single-token decode, mesh-sharded.

decode shapes lower ``decode_step`` — one new token against a KV/state
cache of ``seq_len``; ``long_500k`` allocates a sliding-window ring of
``cfg.long_context_window`` instead (sub-quadratic + sub-linear memory),
and recurrent families carry O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import cache_shardings, param_shardings
from repro.models.model import (decode_step, init_cache, init_params,
                                prefill)


def serve_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring size for a decode cache over a context of ``seq_len``."""
    if cfg.family == "ssm":
        return 1                       # recurrent state only
    if seq_len > 65536:                # long-context: sliding-window ring
        return cfg.long_context_window
    return seq_len


def make_serve_fns(cfg: ModelConfig, mesh, batch: int, seq_len: int,
                   dtype=jnp.float32, *, key=None):
    """Returns (prefill_jit, decode_jit, specs) with mesh shardings.

    prefill(params, tokens[, prefix_embeds]) -> (logits, cache)
    decode(params, token, cache) -> (logits, cache)

    ``key`` shapes the parameter tree (it is only ever consumed under
    ``jax.eval_shape``): pass the caller's init key — or a
    ``ShapeDtypeStruct`` — to make the stream explicit; ``None`` uses an
    abstract key struct, so no literal PRNG key is baked in here.
    """
    if key is None:
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch_shards = 1
    for a in axes:
        n_batch_shards *= mesh.shape[a]
    if batch % max(n_batch_shards, 1) != 0:
        axes = ()                      # e.g. long_500k batch=1: replicate
    b_spec = P(axes if axes else None)
    rep = NamedSharding(mesh, P())
    p_sh = lambda shape: param_shardings(cfg, shape, mesh, stacked=False)
    W = serve_cache_len(cfg, seq_len)

    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, batch, W, dtype))
    c_sh = cache_shardings(cfg, cache_shape, mesh)
    params_shape = jax.eval_shape(lambda k: init_params(cfg, k, dtype),
                                  key)
    psh = p_sh(params_shape)

    def _prefill(params, tokens, prefix_embeds=None):
        return prefill(cfg, params, tokens, prefix_embeds, cache_len=W)

    def _decode(params, token, cache):
        return decode_step(cfg, params, token, cache)

    # prefill cache out-sharding is left to propagation (requesting the
    # ring layout here forces an SPMD full-rematerialization inside the
    # layer scan); decode's explicit in_shardings re-lay it out once.
    prefill_jit = jax.jit(
        _prefill,
        in_shardings=(psh, NamedSharding(mesh, b_spec), None)
        if cfg.frontend != "none" else (psh, NamedSharding(mesh, b_spec)),
        out_shardings=(NamedSharding(mesh, b_spec), None))
    decode_jit = jax.jit(
        _decode,
        in_shardings=(psh, NamedSharding(mesh, b_spec), c_sh),
        out_shardings=(NamedSharding(mesh, b_spec), c_sh),
        donate_argnums=(2,))
    return prefill_jit, decode_jit, {
        "params": psh, "cache": c_sh, "cache_shape": cache_shape,
        "params_shape": params_shape, "batch_spec": b_spec}
