"""Sharding rules for the (pod, data, model) production mesh.

Parameters are named-sharded by leaf-path rules operating on *trailing*
dimensions, so the same table serves plain trees, layer-stacked trees
(leading L), and federated agent-stacked trees (leading K).

Federation mapping (DESIGN.md §3):
  fed_axis="data": agents live on every (pod, data) rank -> K = pods*data;
                   per-agent batch is unsharded (local).
  fed_axis="pod" : one agent per pod -> K = pods; the data axis shards the
                   agent's batch (and could FSDP params; we keep params
                   model-sharded + data-replicated, optimizer state too).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Lane mesh: 1-D data-parallel layout for the engine's scenario sweeps
# ---------------------------------------------------------------------------


#: lane-mesh override stack (see :func:`use_lane_mesh`); the top entry —
#: which may be None, meaning "no sharding" — replaces the default
#: local-devices mesh everywhere the engine asks for one.
_LANE_MESH: list = []


@contextlib.contextmanager
def use_lane_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` as the engine's lane mesh for the dynamic extent
    of the context — how the sweep service points the whole lane-batching
    stack (init/window/one-shot programs, padding) at a process-spanning
    mesh without threading a mesh argument through every layer.  Passing
    None disables lane sharding entirely."""
    _LANE_MESH.append(mesh)
    try:
        yield mesh
    finally:
        _LANE_MESH.pop()


def lane_mesh(devices=None, spanning: bool = False) -> Optional[Mesh]:
    """1-D ``("lane",)`` mesh over the local devices, used by the engine
    to spread a flattened lane×seed scenario batch (DESIGN.md §2).
    Returns None on a single device — the identity layout, so CPU tests
    and single-chip runs skip sharding entirely.

    ``spanning=True`` builds the mesh over **all** processes' devices
    (``jax.devices()``), the process-spanning layout the sweep service
    uses after :func:`init_distributed`: every process dispatches the
    same program and XLA moves each row's work to the process owning its
    shard (DESIGN.md §12)."""
    if devices is None:
        if _LANE_MESH:
            return _LANE_MESH[-1]
        devices = jax.devices() if spanning else jax.local_devices()
    devs = list(devices)
    if len(devs) <= 1:
        return None
    return Mesh(np.asarray(devs), ("lane",))


def lane_sharding(mesh: Optional[Mesh], n_rows: int) \
        -> Optional[NamedSharding]:
    """NamedSharding splitting a leading batch axis of size ``n_rows``
    over the lane mesh; None (replicate — the identity layout) without a
    mesh or when the batch does not divide the device count evenly (the
    engine pads batches to :func:`padded_rows` precisely so this keeps
    dividing)."""
    if mesh is None or n_rows % mesh.size != 0:
        return None
    return NamedSharding(mesh, P("lane"))


def spans_processes(mesh: Optional[Mesh]) -> bool:
    """True when the mesh holds devices from more than one process."""
    if mesh is None:
        return False
    return len({d.process_index for d in mesh.devices.flat}) > 1


def lane_out_sharding(mesh: Optional[Mesh], n_rows: int) \
        -> Optional[NamedSharding]:
    """Output sharding for lane-batched programs: row-sharded like the
    inputs on a local mesh, but **fully replicated** on a
    process-spanning mesh so every host can pull complete histories for
    summaries/checkpoints (a cross-process row-sharded output would be
    only partially addressable on each host)."""
    s = lane_sharding(mesh, n_rows)
    if s is not None and spans_processes(mesh):
        return NamedSharding(mesh, P())
    return s


def padded_rows(mesh: Optional[Mesh], n_rows: int) -> int:
    """Smallest multiple of the lane-mesh device count ≥ ``n_rows``
    (``n_rows`` itself without a mesh).  The engine pads the flattened
    lane×seed batch to this size with duplicate rows — sliced off before
    summaries — so uneven batches shard over the mesh instead of falling
    back to the identity layout."""
    if mesh is None or n_rows % mesh.size == 0:
        return n_rows
    return ((n_rows + mesh.size - 1) // mesh.size) * mesh.size


def init_distributed(coordinator_address: str, num_processes: int,
                     process_id: int) -> None:
    """Bring up the cross-process runtime for a spanning lane mesh.

    On the CPU backend jax's cross-process collectives need the gloo
    transport, and the flag must land **before** the backend
    initializes — ``jax.distributed.initialize`` alone leaves the
    default in place and the first spanning dispatch fails with
    "Multiprocess computations aren't implemented on the CPU backend".
    No-op for a single process."""
    if num_processes <= 1:
        return
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass    # older jaxlib without the option: GPU/TPU transports only
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def global_rows(mesh: Mesh, arr) -> jax.Array:
    """Assemble a process-spanning global array from a host-local copy
    of the full ``(R, ...)`` batch: every process holds the same host
    value (sweep operands are derived deterministically from the grid)
    and contributes the shards of the rows its devices own."""
    sharding = NamedSharding(mesh, P("lane"))
    arr = np.asarray(arr)
    return jax.make_array_from_callback(
        arr.shape, sharding, lambda idx: arr[idx])


def host_assignment(costs, n_hosts: int) -> list:
    """Greedy longest-processing-time schedule: ``assign[i]`` is the
    host owning group ``i``, balancing summed cost per host.  The
    work-partitioning fallback when processes cannot form a spanning
    mesh — uneven lane groups land on the least-loaded host (ties to
    the lowest rank) so no process idles while another drains a long
    tail."""
    costs = [float(c) for c in costs]
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], i))
    loads = [0.0] * max(int(n_hosts), 1)
    assign = [0] * len(costs)
    for i in order:
        h = min(range(len(loads)), key=lambda j: (loads[j], j))
        assign[i] = h
        loads[h] += costs[i]
    return assign

# leaf name -> trailing dim that gets the "model" axis
_MODEL_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "bq", "bk", "bv",
               "w_uq", "w_uk", "w_uv", "lm_head"}
_MODEL_SECOND = {"wo", "w_down"}
_REPLICATE = {"router", "norm_attn", "norm_mlp", "final_norm", "norm_m",
              "norm_s", "frontend_proj", "w_dq", "w_dkv"}


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def fed_axes(cfg: ModelConfig, mesh: Mesh) -> Tuple[str, ...]:
    has_pod = "pod" in mesh.shape
    if cfg.fed_axis == "pod":
        return ("pod",) if has_pod else ()
    if cfg.fed_axis == "all":
        # TP-free federation: one agent per chip (beyond-paper sharding,
        # EXPERIMENTS.md §Perf) — no tensor parallelism, the only
        # collectives left are the paper's aggregation + agreement.
        return ("pod", "data", "model") if has_pod else ("data", "model")
    return ("pod", "data") if has_pod else ("data",)


def n_agents(cfg: ModelConfig, mesh: Mesh) -> int:
    axes = fed_axes(cfg, mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)


def batch_axes(cfg: ModelConfig, mesh: Mesh) -> Tuple[str, ...]:
    """Axes sharding the per-agent batch dimension."""
    if cfg.fed_axis == "pod":
        return ("data",)
    if getattr(cfg, "intra_agent_dp", False) and cfg.fed_axis == "data":
        return ("model",)
    return ()


def _path_names(path) -> list:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return names


def param_spec(cfg: ModelConfig, path, leaf, mesh: Mesh,
               stacked: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = leaf.ndim
    spec = [None] * ndim
    if cfg.fed_axis == "all" or getattr(cfg, "intra_agent_dp", False):
        # agent params replicated within the agent's chip group (TP-free)
        if stacked:
            axes = fed_axes(cfg, mesh)
            spec[0] = axes if axes else None
        return P(*spec)
    model_ok = mesh_axis_size(mesh, "model") > 1

    in_recurrent = (cfg.family == "ssm") or ("ssm" in names) \
        or ("m" in names) or ("s" in names)
    e = cfg.moe.n_experts if cfg.moe is not None else 0
    expert_leaf = (cfg.moe is not None and "mlp" in names
                   and name in ("w_gate", "w_up", "w_down")
                   and "shared" not in names)

    msize = mesh_axis_size(mesh, "model")

    def put(dim, axis="model", size=None):
        if leaf.shape[dim] % (size or msize) == 0:
            spec[dim] = axis

    if model_ok and not in_recurrent and name not in _REPLICATE:
        if name == "embed":
            put(-2)                     # vocab-parallel
        elif expert_leaf and e % msize == 0:
            put(-3)                     # expert-parallel
        elif name in _MODEL_LAST:
            put(-1)
        elif name in _MODEL_SECOND:
            put(-2)
    # FSDP-over-layers: shard the layer-stack dim over "data" so the layer
    # scan gathers one layer's weights at a time (fed_axis="pod" archs that
    # would not otherwise fit, e.g. grok-1-314b).
    dsize = mesh_axis_size(mesh, "data")
    if (getattr(cfg, "fsdp_layers", False) and names
            and names[0] == "blocks" and dsize > 1):
        ldim = 1 if stacked else 0
        if ldim < ndim and spec[ldim] is None \
                and leaf.shape[ldim] % dsize == 0:
            spec[ldim] = "data"
    if stacked:        # leaf already carries the leading K dim
        axes = fed_axes(cfg, mesh)
        spec[0] = axes if axes else None
    return P(*spec)


def param_shardings(cfg: ModelConfig, params_shape, mesh: Mesh,
                    stacked: bool = False):
    """Tree of NamedShardings matching a params(-shaped) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(cfg, path, leaf, mesh, stacked)),
        params_shape)


def batch_spec(cfg: ModelConfig, mesh: Mesh, stacked: bool = True) -> P:
    """Spec for token batches: (K, b, S) if stacked else (B, S)."""
    fa = fed_axes(cfg, mesh)
    ba = batch_axes(cfg, mesh)
    if stacked:
        return P(fa if fa else None, ba if ba else None)
    # serving: batch over every non-model axis
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if axes else None)


def cache_shardings(cfg: ModelConfig, cache_shape, mesh: Mesh):
    """KV/state caches: batch dim over (pod, data); heads/features over
    model where the layout allows."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_ok = mesh_axis_size(mesh, "model") > 1

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if name in ("pos", "slot_pos"):
            return NamedSharding(mesh, P())
        nd = leaf.ndim
        msize = mesh_axis_size(mesh, "model")
        bsize = 1
        for a in axes:
            bsize *= mesh_axis_size(mesh, a)
        s = [None] * nd
        # leading dims: (L, B, ...) for block caches
        if nd >= 2 and leaf.shape[1] % max(bsize, 1) == 0:
            s[1] = axes if axes else None
        if model_ok:
            if name in ("k", "v") and nd == 5:      # (L,B,W,Hkv,hd)
                if cfg.n_kv_heads % msize == 0:
                    s[3] = "model"
                elif leaf.shape[2] % msize == 0:
                    s[2] = "model"                  # sequence-sharded cache
            elif name in ("c", "k_rope") and nd == 4:   # MLA latent (L,B,W,r)
                if leaf.shape[2] % msize == 0:
                    s[2] = "model"
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def shard_hint(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op without a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (ValueError, RuntimeError):
        return x


def ctx_mesh():
    """The mesh installed via jax.set_mesh (None outside a mesh context)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        return m if m and m.shape else None
    except Exception:
        return None


def maybe_shard(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context, so
    model code can pin layouts for the production mesh without breaking
    CPU tests."""
    m = ctx_mesh()
    if m is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x
