"""Continuous-batching serving demo: the aggregated transformer policy
behind the `repro.serving` engine, driven by simulated user traffic.

32+ requests arrive staggered (Poisson at --rate req/s); the fixed-slot
engine prefills each into a free slot, decodes all occupied slots in one
jitted step per tick, and recycles slots as budgets complete.  Per-request
latency records and queue-depth/slot-occupancy gauges stream through
`repro.obs`; the summary reports p50/p99 latency and aggregate tokens/sec.

  PYTHONPATH=src python examples/serve_decode.py --requests 32 --slots 4
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro import make_env, obs, resolve
from repro.serving import PolicyServer, engine_for_policy, make_traffic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean request arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--offline", action="store_true",
                    help="virtual-clock replay (deterministic; no "
                         "queueing delay in the latencies)")
    args = ap.parse_args()

    env = make_env("cartpole(horizon=32)")
    policy = resolve(
        "policy",
        f"transformer(arch='{args.arch}', n_layers=2, d_model=64, "
        f"n_heads=2)", env=env)

    # one root key, split per consumer: init here, traffic obs vectors are
    # host-side numpy (traffic.py) and never touch the jax key stream
    key_init, _key_spare = jax.random.split(jax.random.PRNGKey(args.seed))
    params = policy.init(key_init)

    engine = engine_for_policy(policy, params, slots=args.slots,
                               max_new=args.max_new, max_prompt=8)
    server = PolicyServer(engine)           # warmup compiles all programs
    traffic = make_traffic(args.requests, seed=args.seed,
                           rate_rps=args.rate, max_new=args.max_new,
                           obs_dim=env.obs_dim)

    with obs.telemetry() as rec:
        report = server.run_offline(traffic) if args.offline \
            else server.run(traffic)
        n_records = len(rec.stream("serve.request"))
        peak_busy = max((r["slots_busy"] for r in rec.stream("serve.gauge")),
                        default=0)

    s = report.summary()
    obs.progress(f"{args.requests} requests on {args.slots} slots "
                 f"({'offline' if args.offline else 'realtime'}): "
                 f"p50={s['latency_p50_ms']}ms p99={s['latency_p99_ms']}ms "
                 f"ttft_p50={s['ttft_p50_ms']}ms "
                 f"{s['tokens_per_s']} tok/s "
                 f"({s['total_tokens']} tokens in {s['wall_s']}s)")
    obs.progress(f"telemetry: {n_records} serve.request records, "
                 f"peak occupancy {peak_busy}/{args.slots} slots")
    for r in report.results[:4]:
        obs.progress(f"  uid={r.uid}: {r.tokens}")


if __name__ == "__main__":
    main()
