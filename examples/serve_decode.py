"""Batched serving demo: prefill + greedy decode with ring KV cache,
including the sliding-window long-context mode (long_500k analogue).

  PYTHONPATH=src python examples/serve_decode.py --arch llama3.2-1b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import get_config, reduced
from repro.models.model import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--window", type=int, default=0,
                    help=">0: sliding-window ring cache of this size")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = None
    if cfg.frontend != "none":
        pe = jax.random.normal(key, (B, cfg.n_prefix_embeds, cfg.d_model))
    W = args.window or (S + cfg.n_prefix_embeds + args.gen)
    window = args.window or None

    pf = jax.jit(lambda p, t, e: prefill(cfg, p, t, e, cache_len=W,
                                         window=window))
    dc = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    logits, cache = pf(params, toks, pe)
    tok = jnp.argmax(logits[:, -1], -1)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = dc(params, tok, cache)
        tok = jnp.argmax(logits[:, 0], -1)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.gen - 1, 1)
    obs.progress(f"{cfg.name} cache_len={W} window={window}: "
                 f"{dt*1e3:.2f} ms/token on CPU")
    obs.progress(f"generated: {[int(x) for x in jnp.stack(outs, 1)[0][:16]]}")


if __name__ == "__main__":
    main()
