"""Paper Fig. 1: speed-up of DecByzPG with federation size K (honest case).

One declarative Experiment over the K axis through the fused engine,
seeds vmapped; K=1 recovers PAGE-PG.

  PYTHONPATH=src python examples/federation_speedup.py [--iters 30]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import Experiment, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    obs.progress(f"== DecByzPG speed-up in K (alpha=0, {args.seeds} seeds); "
                 f"K=1 is PAGE-PG ==")
    exp = Experiment(algo="decbyzpg", env="cartpole(horizon=200)",
                     T=args.iters, seeds=args.seeds,
                     axes={"K": (1, 5, 13)}, N=20, B=4, eta=2e-2,
                     override=lambda c: dataclasses.replace(
                         c, kappa=4 if c.K > 1 else 0))
    res = exp.run()
    curves = {scn.K: out for scn, out in res.items()}
    for K, out in curves.items():
        obs.progress(f"K={K:2d}: final return {out['final_return_mean']:6.1f}"
                     f"±{out['final_return_ci95']:.1f} after "
                     f"{out['samples'][:, -1].mean():.0f} samples/agent")
    # return achieved at a fixed per-agent sample budget
    budget = curves[13]["samples"].mean(axis=0)[-1]
    obs.progress(f"\nreturn at equal per-agent sample budget ({budget:.0f}):")
    for K, out in curves.items():
        samples = out["samples"].mean(axis=0)
        idx = min(int(np.searchsorted(samples, budget)),
                  out["returns_mean"].shape[0] - 1)
        r = out["returns_mean"][max(idx - 2, 0):idx + 1].mean()
        obs.progress(f"  K={K:2d}: {r:.1f}")


if __name__ == "__main__":
    main()
