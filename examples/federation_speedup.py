"""Paper Fig. 1: speed-up of DecByzPG with federation size K (honest case).

  PYTHONPATH=src python examples/federation_speedup.py [--iters 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
from repro.rl.envs import make_cartpole


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()
    env = make_cartpole(horizon=200)
    print("== DecByzPG speed-up in K (alpha=0); K=1 is PAGE-PG ==")
    curves = {}
    for K in (1, 5, 13):
        out = run_decbyzpg(env, DecByzPGConfig(
            K=K, N=20, B=4, kappa=4 if K > 1 else 0, eta=2e-2, seed=0),
            T=args.iters)
        curves[K] = out
        print(f"K={K:2d}: final return {np.mean(out['returns'][-5:]):6.1f} "
              f"after {out['samples'][-1]} samples/agent")
    # return achieved at a fixed per-agent sample budget
    budget = curves[13]["samples"][-1]
    print(f"\nreturn at equal per-agent sample budget ({budget}):")
    for K, out in curves.items():
        idx = int(np.searchsorted(out["samples"], budget))
        idx = min(idx, len(out["returns"]) - 1)
        print(f"  K={K:2d}: {np.mean(out['returns'][max(idx-2,0):idx+1]):.1f}")


if __name__ == "__main__":
    main()
