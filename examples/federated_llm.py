"""Byzantine-tolerant federated LLM training (the paper's optimizer applied
to an assigned architecture): 6 agents, 1 Byzantine sending LargeNoise,
RFA aggregation + GDA agreement, PAGE coin via Common-Sample.

Runs on the flat (K, D) parameter stack (DESIGN.md §3): every agent's
transformer ravels into one row, the trailing D axis is sharded over the
mesh's "model" axis, and robust aggregation goes through the registry
aggregators' sharded Gram path — one K² psum, no parameter gather.

  PYTHONPATH=src python examples/federated_llm.py --arch qwen2.5-3b
  # exercise the sharded path on CPU:
  PYTHONPATH=src python examples/federated_llm.py --fake-devices 4
"""
import argparse
import os
import sys

from repro import obs

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--agents", type=int, default=6)
    ap.add_argument("--byz", type=int, default=1)
    ap.add_argument("--tree", action="store_true",
                    help="legacy tree-sharded trainer instead of the flat "
                         "(K, D) stack")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="split one host into N XLA devices (set before "
                         "jax import) so the sharded path engages on CPU")
    args = ap.parse_args()
    if args.fake_devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.fake_devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro import get_config, reduced
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.distributed.fed_trainer import (
        FedConfig, common_sample_coin, fed_train_step, fed_train_step_flat,
        flat_fed_state_shardings, init_fed_state, init_flat_fed_state)

    cfg = reduced(get_config(args.arch))
    fed = FedConfig(aggregator="rfa", kappa=3, n_byz=args.byz,
                    attack="large_noise", lr=2e-3, page_p=0.25)
    K = args.agents
    key = jax.random.PRNGKey(0)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 64, 2, K, seed=0))
    mask = jnp.asarray(np.arange(K) < args.byz)

    devs = jax.devices()
    mesh = Mesh(np.asarray(devs), ("model",)) if len(devs) > 1 else None

    if args.tree:
        state = init_fed_state(cfg, fed, K, key)
        steps = {c: jax.jit(lambda s, b, m, k, c=c: fed_train_step(
            cfg, fed, s, b, m, k, large=c)) for c in (True, False)}
        path = "tree-sharded"
    else:
        state, unravel = init_flat_fed_state(cfg, fed, K, key, mesh=mesh)
        D = state.theta.shape[1]
        sharded = mesh is not None
        jit_kw = {}
        if sharded:
            sh = flat_fed_state_shardings(
                mesh, jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                    state))
            jit_kw = dict(in_shardings=(sh, None, None, None),
                          out_shardings=(sh, None), donate_argnums=(0,))
        steps = {c: jax.jit(
            lambda s, b, m, k, c=c: fed_train_step_flat(
                cfg, fed, s, unravel, b, m, k, large=c, sharded=sharded),
            **jit_kw) for c in (True, False)}
        path = (f"flat (K, D={D}) stack, "
                + (f"D-sharded over {len(devs)} devices" if sharded
                   else "single device"))

    obs.progress(f"{cfg.name}: K={K}, {args.byz} Byzantine (LargeNoise), "
                 f"RFA + GDA(kappa=3), PAGE p={fed.page_p} — {path}")
    for t in range(args.steps):
        c = common_sample_coin(t, 0, fed.page_p)
        key, k = jax.random.split(key)
        state, m = steps[c](state, pipe.batch(t), mask, k)
        obs.progress(f"step {t:3d} coin={'N' if c else 'B'} "
                     f"honest_loss={float(m['loss']):.4f} "
                     f"diam={float(m['diameter']):.2e}")


if __name__ == "__main__":
    main()
