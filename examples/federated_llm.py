"""Byzantine-tolerant federated LLM training (the paper's optimizer applied
to an assigned architecture): 6 agents, 1 Byzantine sending LargeNoise,
bucketed-RFA aggregation + GDA agreement, PAGE coin via Common-Sample.

  PYTHONPATH=src python examples/federated_llm.py --arch qwen2.5-3b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.fed_trainer import (FedConfig, common_sample_coin,
                                           fed_train_step, init_fed_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--agents", type=int, default=6)
    ap.add_argument("--byz", type=int, default=1)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    fed = FedConfig(aggregator="rfa", kappa=3, n_byz=args.byz,
                    attack="large_noise", lr=2e-3, page_p=0.25)
    K = args.agents
    key = jax.random.PRNGKey(0)
    state = init_fed_state(cfg, fed, K, key)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 64, 2, K,
                                    seed=0))
    mask = jnp.asarray(np.arange(K) < args.byz)
    steps = {c: jax.jit(lambda s, b, m, k, c=c: fed_train_step(
        cfg, fed, s, b, m, k, large=c)) for c in (True, False)}

    print(f"{cfg.name}: K={K}, {args.byz} Byzantine (LargeNoise), "
          f"RFA + GDA(kappa=3), PAGE p={fed.page_p}")
    for t in range(args.steps):
        c = common_sample_coin(t, 0, fed.page_p)
        key, k = jax.random.split(key)
        state, m = steps[c](state, pipe.batch(t), mask, k)
        print(f"step {t:3d} coin={'N' if c else 'B'} "
              f"honest_loss={float(m['loss']):.4f} "
              f"diam={float(m['diameter']):.2e}", flush=True)


if __name__ == "__main__":
    main()
