"""Quickstart: DecByzPG on CartPole with Byzantine agents (paper Fig. 2).

13 agents, 3 Byzantine running the AvgZero attack; DecByzPG (bucketed RFA
aggregation + GDA averaging agreement) vs the naive Dec-PAGE-PG baseline.

  PYTHONPATH=src python examples/quickstart.py [--iters 40]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.decbyzpg import DecByzPGConfig, run_decbyzpg
from repro.rl.envs import make_cartpole


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--attack", default="avg_zero")
    args = ap.parse_args()

    env = make_cartpole(horizon=200)
    common = dict(K=13, n_byz=3, attack=args.attack, N=20, B=4,
                  eta=2e-2, seed=0)
    print(f"== DecByzPG (robust) vs Dec-PAGE-PG (naive), attack="
          f"{args.attack}, 3/13 Byzantine ==")
    robust = run_decbyzpg(env, DecByzPGConfig(
        aggregator="rfa", kappa=5, **common), T=args.iters)
    naive = run_decbyzpg(env, DecByzPGConfig(
        aggregator="mean", kappa=0, **common), T=args.iters)
    print(f"{'samples/agent':>14s} {'DecByzPG':>10s} {'Dec-PAGE-PG':>12s}")
    for i in range(0, args.iters, max(args.iters // 10, 1)):
        print(f"{robust['samples'][i]:14d} {robust['returns'][i]:10.1f} "
              f"{naive['returns'][i]:12.1f}")
    print(f"final (mean of last 5): DecByzPG="
          f"{np.mean(robust['returns'][-5:]):.1f}  "
          f"Dec-PAGE-PG={np.mean(naive['returns'][-5:]):.1f}")
    print(f"honest parameter diameter under attack: "
          f"{robust['diameter'][-1]:.2e} (agreement keeps agents synced)")


if __name__ == "__main__":
    main()
