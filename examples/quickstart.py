"""Quickstart: DecByzPG on CartPole with Byzantine agents (paper Fig. 2).

13 agents, 3 Byzantine running the AvgZero attack; DecByzPG (bucketed RFA
aggregation + GDA averaging agreement) vs the naive Dec-PAGE-PG baseline.
One declarative Experiment sweeps the aggregator axis: each arm is a
single compiled scan program with the seed batch vmapped, and any
``--attack`` value may be a parameterized component spec, e.g.
``--attack "large_noise(sigma=10)"``.

  PYTHONPATH=src python examples/quickstart.py [--iters 40] [--seeds 3]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro import Experiment, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--attack", default="avg_zero")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()

    exp = Experiment(
        algo="decbyzpg", env="cartpole(horizon=200)", T=args.iters,
        seeds=args.seeds, axes={"aggregator": ("rfa", "mean")},
        K=13, n_byz=3, attack=args.attack, N=20, B=4, eta=2e-2,
        override=lambda c: dataclasses.replace(
            c, kappa=0 if c.aggregator.name == "mean" else 5))
    obs.progress(f"== DecByzPG (robust) vs Dec-PAGE-PG (naive), attack="
                 f"{args.attack}, 3/13 Byzantine, {args.seeds} seeds ==")
    res = exp.run()
    robust = res.sel(aggregator="rfa")
    naive = res.sel(aggregator="mean")

    obs.progress(f"{'samples/agent':>14s} {'DecByzPG':>16s} {'Dec-PAGE-PG':>16s}")
    budget = robust["samples"].mean(axis=0)
    for i in range(0, args.iters, max(args.iters // 10, 1)):
        obs.progress(f"{budget[i]:14.0f} "
                     f"{robust['returns_mean'][i]:8.1f}±{robust['returns_ci95'][i]:<7.1f} "
                     f"{naive['returns_mean'][i]:8.1f}±{naive['returns_ci95'][i]:<7.1f}")
    obs.progress(f"final (mean of last 3, ±95% CI over seeds): "
                 f"DecByzPG={robust['final_return_mean']:.1f}"
                 f"±{robust['final_return_ci95']:.1f}  "
                 f"Dec-PAGE-PG={naive['final_return_mean']:.1f}"
                 f"±{naive['final_return_ci95']:.1f}")
    obs.progress(f"honest parameter diameter under attack: "
                 f"{robust['diameter'][:, -1].mean():.2e} "
                 f"(agreement keeps agents synced)")


if __name__ == "__main__":
    main()
