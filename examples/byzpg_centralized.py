"""Centralized ByzPG (paper Algorithm 1 / Figs. 5-6): the warm-up method —
trusted server, robust aggregation of worker PG estimates, PAGE small-batch
steps at the server only.  Both arms run as one declarative Experiment with
the aggregator axis swept and the seed batch vmapped.

  PYTHONPATH=src python examples/byzpg_centralized.py [--iters 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import Experiment, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--attack", default="large_noise")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    exp = Experiment(algo="byzpg", env="cartpole(horizon=200)",
                     T=args.iters, seeds=args.seeds,
                     axes={"aggregator": ("rfa", "mean")},
                     K=13, n_byz=3, attack=args.attack, N=20, B=4, eta=2e-2)
    res = exp.run()
    robust = res.sel(aggregator="rfa")
    naive = res.sel(aggregator="mean")
    obs.progress(f"attack={args.attack}, 3/13 Byzantine (centralized, "
                 f"{args.seeds} seeds)")
    obs.progress(f"ByzPG (RFA):        final return "
                 f"{robust['final_return_mean']:.1f}"
                 f"±{robust['final_return_ci95']:.1f}")
    obs.progress(f"Fed-PAGE-PG (mean): final return "
                 f"{naive['final_return_mean']:.1f}±{naive['final_return_ci95']:.1f}")


if __name__ == "__main__":
    main()
