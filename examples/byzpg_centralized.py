"""Centralized ByzPG (paper Algorithm 1 / Figs. 5-6): the warm-up method —
trusted server, robust aggregation of worker PG estimates, PAGE small-batch
steps at the server only.  Both arms run as one fused-engine ScenarioGrid
call with the seed batch vmapped.

  PYTHONPATH=src python examples/byzpg_centralized.py [--iters 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.engine import Scenario, ScenarioGrid, run_grid
from repro.rl.envs import make_cartpole


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--attack", default="large_noise")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    env = make_cartpole(horizon=200)
    grid = ScenarioGrid(seeds=tuple(range(args.seeds)), K=(13,), n_byz=(3,),
                        attack=(args.attack,), aggregator=("rfa", "mean"))
    res = run_grid(env, grid, args.iters, algo="byzpg", N=20, B=4, eta=2e-2)
    robust = res[Scenario(13, 3, args.attack, "rfa", "mda")]
    naive = res[Scenario(13, 3, args.attack, "mean", "mda")]
    print(f"attack={args.attack}, 3/13 Byzantine (centralized, "
          f"{args.seeds} seeds)")
    print(f"ByzPG (RFA):        final return "
          f"{robust['final_return_mean']:.1f}"
          f"±{robust['final_return_ci95']:.1f}")
    print(f"Fed-PAGE-PG (mean): final return "
          f"{naive['final_return_mean']:.1f}±{naive['final_return_ci95']:.1f}")


if __name__ == "__main__":
    main()
