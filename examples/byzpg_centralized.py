"""Centralized ByzPG (paper Algorithm 1 / Figs. 5-6): the warm-up method —
trusted server, robust aggregation of worker PG estimates, PAGE small-batch
steps at the server only.

  PYTHONPATH=src python examples/byzpg_centralized.py [--iters 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.byzpg import ByzPGConfig, run_byzpg
from repro.rl.envs import make_cartpole


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--attack", default="large_noise")
    args = ap.parse_args()
    env = make_cartpole(horizon=200)
    common = dict(K=13, n_byz=3, attack=args.attack, N=20, B=4, eta=2e-2,
                  seed=0)
    robust = run_byzpg(env, ByzPGConfig(aggregator="rfa", **common),
                       T=args.iters)
    naive = run_byzpg(env, ByzPGConfig(aggregator="mean", **common),
                      T=args.iters)
    print(f"attack={args.attack}, 3/13 Byzantine (centralized)")
    print(f"ByzPG (RFA):        final return "
          f"{np.mean(robust['returns'][-5:]):.1f}")
    print(f"Fed-PAGE-PG (mean): final return "
          f"{np.mean(naive['returns'][-5:]):.1f}")


if __name__ == "__main__":
    main()
