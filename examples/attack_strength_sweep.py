"""Resilience across attack strengths (paper Fig. 3 analogue), as a
lane-batched sweep.

DecByzPG vs the naive Dec-PAGE-PG baseline over a ladder of LargeNoise
sigmas. ``sigma`` is a *traced* attack kwarg (the attack factory marks it
batchable), so each aggregator arm — all its sigma points × all seeds —
runs as ONE compiled lane-batched program (DESIGN.md §2): 2 compiles for
the whole figure instead of 2 × len(sigmas).

  PYTHONPATH=src python examples/attack_strength_sweep.py \
      [--iters 40] [--seeds 3] [--sigmas 1,10,50,100,200]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro import Experiment, obs
from repro.core import engine



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--sigmas", default="1,10,50,100,200")
    args = ap.parse_args()
    sigmas = tuple(float(s) for s in args.sigmas.split(","))

    exp = Experiment(
        algo="decbyzpg", env="cartpole(horizon=200)", T=args.iters,
        seeds=args.seeds,
        axes={"attack": tuple(f"large_noise(sigma={s})" for s in sigmas),
              "aggregator": ("rfa", "mean")},
        K=13, n_byz=3, N=20, B=4, eta=2e-2,
        override=lambda c: dataclasses.replace(
            c, kappa=0 if c.aggregator.name == "mean" else 5))
    engine.clear_cache()
    res = exp.run()
    n_programs = engine.compile_count()

    obs.progress(f"== LargeNoise strength sweep, 3/13 Byzantine, "
                 f"{args.seeds} seeds; {len(res)} scenarios in "
                 f"{n_programs} compiled programs ==")
    obs.progress(f"{'sigma':>8s} {'DecByzPG (rfa)':>18s} "
                 f"{'Dec-PAGE-PG (mean)':>20s}")
    for s in sigmas:
        robust = res.sel(attack=f"large_noise(sigma={s})",
                         aggregator="rfa")
        naive = res.sel(attack=f"large_noise(sigma={s})",
                        aggregator="mean")
        obs.progress(f"{s:8.0f} "
                     f"{robust['final_return_mean']:9.1f}"
                     f"±{robust['final_return_ci95']:<7.1f} "
                     f"{naive['final_return_mean']:11.1f}"
                     f"±{naive['final_return_ci95']:<7.1f}")
    obs.progress("\nDecByzPG holds its return as sigma grows; the naive mean "
                 "baseline degrades (the paper's Fig. 3 phenomenon).")


if __name__ == "__main__":
    main()
