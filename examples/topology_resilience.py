"""Topology resilience: DecByzPG across gossip graphs (DESIGN.md §5).

The paper's Algorithm 3 assumes all-to-all broadcast; this sweep asks what
partial connectivity costs. One declarative Experiment sweeps the
``topology`` axis under a per-receiver-equivocating attack and reports,
per graph, the static diagnostics (density, min degree, spectral gap)
next to the learning outcome and the honest parameter diameter Δ₂ — the
agreement-quality number Theorem 2's O(2^-κ) bias term is about. The
star graph is the FedPG-BR trusted-server pattern expressed as a graph:
connectivity 1, no decentralized contraction.

  PYTHONPATH=src python examples/topology_resilience.py [--iters 40]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import Experiment, obs
from repro.topology import resolve_topology

TOPOLOGIES = ("complete", "ring(k=4)", "small_world(k=4, beta=0.3)",
              "star")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--attack", default="avg_zero")
    ap.add_argument("--K", type=int, default=13)
    ap.add_argument("--n-byz", type=int, default=3)
    args = ap.parse_args()

    exp = Experiment(
        algo="decbyzpg", env="cartpole(horizon=200)", T=args.iters,
        seeds=args.seeds, axes={"topology": TOPOLOGIES},
        K=args.K, n_byz=args.n_byz, attack=args.attack, per_receiver=True,
        aggregator="rfa", agreement="gda", kappa=5, N=20, B=4, eta=2e-2)
    obs.progress(f"== DecByzPG topology sweep: K={args.K}, {args.n_byz} Byzantine "
                 f"({args.attack}, per-receiver equivocation), {args.seeds} seeds ==")
    res = exp.run()

    obs.progress(f"{'topology':>28s} {'density':>8s} {'min_deg':>8s} {'gap':>6s} "
                 f"{'2f+1?':>6s} {'final_return':>14s} {'Δ₂ (diam)':>10s}")
    for spec in TOPOLOGIES:
        topo = resolve_topology(spec, args.K)
        out = res.sel(topology=spec)
        feasible = "yes" if topo.tolerates(args.n_byz) else "NO"
        obs.progress(f"{topo.name:>28s} {topo.density:8.2f} "
                     f"{topo.min_in_degree:8d} {topo.spectral_gap:6.2f} "
                     f"{feasible:>6s} "
                     f"{out['final_return_mean']:7.1f}±{out['final_return_ci95']:<5.1f} "
                     f"{out['final_diameter_mean']:10.2e}")
    obs.progress("\n(min_deg > 2·n_byz is the necessary BFT connectivity "
                 "condition; graphs failing it cannot bound Byzantine influence "
                 "— watch Δ₂ fail to contract on the star.)")


if __name__ == "__main__":
    main()
